"""Benchmark harness: one module per paper table/figure (+ framework perf).

Prints ``name,us_per_call,derived`` CSV per row and dumps the full records
to results/bench.json. The default set is the fast model-free suites;
``--all`` adds the serving benchmarks that build and drive real models
through the coded runtime (``serve_throughput``, ``chaos_resilience``) —
their ``run()`` entries also refresh the committed artifacts
(``BENCH_serve.json``, ``BENCH_chaos.json``) and append one trajectory
snapshot per bench/arch to ``BENCH_history.jsonl``, so ONE command
regenerates every artifact the CI perf-trajectory gate checks.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true",
                    help="include the runtime serving benchmarks "
                         "(serve_throughput, chaos_resilience)")
    args = ap.parse_args()

    from benchmarks import (coded_overhead, fig2_data_loss, fig12_recovery,
                            fig16_straggler, fig17_coverage, multi_failure,
                            roofline_table, tab1_suitability)

    suites = [
        ("fig2_data_loss", fig2_data_loss.run),
        ("fig12_recovery", fig12_recovery.run),
        ("fig16_straggler", fig16_straggler.run),
        ("fig17_coverage", fig17_coverage.run),
        ("tab1_suitability", tab1_suitability.run),
        ("coded_overhead", coded_overhead.run),
        ("coded_overhead_kernels", coded_overhead.run_kernels),
        ("multi_failure", multi_failure.run),
        ("roofline_table", roofline_table.run),
    ]
    if args.all:
        from benchmarks import chaos_resilience, serve_throughput
        suites += [
            ("serve_throughput", serve_throughput.run),
            ("chaos_resilience", chaos_resilience.run),
        ]

    all_results = {}
    print("name,us_per_call,derived")
    for name, fn in suites:
        t0 = time.perf_counter()
        rows = fn()
        us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
        all_results[name] = rows
        for row in rows:
            us_val = next((row[k] for k in row
                           if isinstance(row.get(k), (int, float))
                           and str(k).startswith("us_")), round(us, 1))
            derived = {k: v for k, v in row.items()
                       if not str(k).startswith("us_")}
            print(f"{name},{us_val},\"{derived}\"")

    os.makedirs("/root/repo/results", exist_ok=True)
    with open("/root/repo/results/bench.json", "w") as f:
        json.dump(all_results, f, indent=1, default=str)
    print(f"# wrote results/bench.json with {len(all_results)} suites")


if __name__ == '__main__':
    main()
