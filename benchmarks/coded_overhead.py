"""Paper §5.2/§7: the coded computation's cost structure.

  * runtime overhead of carrying parity: (T+r)/T FLOPs — CONSTANT in device
    count (vs 2x for modular redundancy), measured on the coded GEMM;
  * offline encode cost (amortized: once per weight load);
  * decode (recovery) cost: the close-to-zero claim — compare against the
    GEMM itself and against recompute.
Also sweeps the Pallas kernels (interpret mode) against their jnp oracles.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import CodedDenseSpec, CodeSpec, coded_matmul, \
    make_parity_weights
from repro.kernels import ops


def _time(f, *args, n=20):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(batch=32, k=2048, m=4096) -> list[dict]:
    rows = []
    for T in (4, 8, 16):
        for r in (1, 2):
            kx, kw = jax.random.split(jax.random.PRNGKey(T * 10 + r))
            x = jax.random.normal(kx, (batch, k), jnp.float32)
            w = jax.random.normal(kw, (k, m), jnp.float32) / k ** 0.5
            spec = CodedDenseSpec(CodeSpec(T, r))
            t_enc = _time(jax.jit(
                lambda w: make_parity_weights(w, spec)), w, n=5)
            w_cdc = make_parity_weights(w, spec)
            valid = jnp.ones(T, bool).at[1].set(False)

            plain = jax.jit(lambda x: coded_matmul(x, w, None, spec))
            coded = jax.jit(
                lambda x: coded_matmul(x, w, w_cdc, spec,
                                       jnp.ones(T, bool)))
            recov = jax.jit(lambda x: coded_matmul(x, w, w_cdc, spec, valid))
            t_plain, t_coded, t_rec = (_time(plain, x), _time(coded, x),
                                       _time(recov, x))
            rows.append({
                "T": T, "r": r,
                "flops_overhead_theory": round((T + r) / T, 3),
                "us_plain": round(t_plain, 1),
                "us_coded": round(t_coded, 1),
                "us_coded_recovering": round(t_rec, 1),
                "measured_overhead_x": round(t_coded / t_plain, 2),
                "us_encode_offline": round(t_enc, 1),
            })
    return rows


def run_kernels() -> list[dict]:
    """Pallas kernel micro-bench (interpret mode on CPU: correctness-grade
    numbers; the BlockSpec tiling is the TPU deployment artifact)."""
    rows = []
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (512, 512), jnp.float32)
    w = jax.random.normal(k2, (512, 512), jnp.float32)
    rows.append({"kernel": "matmul",
                 "us_pallas_interp": round(_time(
                     lambda a, b: ops.matmul(a, b), x, w, n=3), 1),
                 "us_jnp_ref": round(_time(
                     lambda a, b: ops.matmul(a, b, use_pallas=False),
                     x, w, n=3), 1)})
    ys = jax.random.normal(k1, (8, 256, 512), jnp.float32)
    parity = ys.sum(0)
    valid = jnp.ones(8, bool).at[3].set(False)
    rows.append({"kernel": "cdc_decode",
                 "us_pallas_interp": round(_time(
                     lambda a, p, v: ops.cdc_decode(a, p, v),
                     ys, parity, valid, n=3), 1),
                 "us_jnp_ref": round(_time(
                     lambda a, p, v: ops.cdc_decode(a, p, v,
                                                    use_pallas=False),
                     ys, parity, valid, n=3), 1)})
    return rows


if __name__ == "__main__":
    for r in run() + run_kernels():
        print(r)
