"""Paper Fig. 17 + §6.3: full-model failure coverage, CDC+2MR vs 2MR.

2MR duplicates every device (linear extra cost). CDC covers ALL devices of a
model-parallel layer with ONE extra device (constant cost, (1 + 1/N)x vs 2x
hardware). The paper's C3D two-way vs three-way distributions show 67%/73%
coverage for CDC+2MR at 2 extra devices vs 44%/36% for 2MR.
"""
from __future__ import annotations

from repro.core.failure import coverage_2mr, coverage_at_budget


# distributed DNN deployments from the paper's Fig. 17 (layers using model
# parallelism with N devices each + other single-device stages)
SYSTEMS = {
    "alexnet-fc2x":   {"mp_layers": [2], "other": 4},
    "vgg16-fc2x":     {"mp_layers": [2, 2], "other": 5},
    "c3d-2dev":       {"mp_layers": [2, 2], "other": 5},
    "c3d-3dev":       {"mp_layers": [3, 3], "other": 5},
}


def run() -> list[dict]:
    rows = []
    for name, sysd in SYSTEMS.items():
        mp_total = sum(sysd["mp_layers"])
        econ = coverage_2mr(mp_total, sysd["other"])
        for budget in (1, 2, 3):
            cov = coverage_at_budget(sysd["mp_layers"], sysd["other"],
                                     budget)
            rows.append({"system": name, "extra_devices": budget,
                         "coverage_2mr": round(cov["coverage_2mr"], 3),
                         "coverage_cdc_2mr": round(cov["coverage_cdc_2mr"],
                                                   3),
                         "hw_cost_full_2mr": econ["hw_cost_2mr"],
                         "hw_cost_full_cdc": round(
                             econ["hw_cost_cdc_2mr"], 3)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
