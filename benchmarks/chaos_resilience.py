"""Chaos resilience benchmark: the paper's headline robustness claims
under *injected* faults instead of hand-placed ones (``repro.faults``).

Three sections, written to ``BENCH_chaos.json`` (repo root):

  1. ``churn`` — a deterministic in-budget churn trace (single-shard
     outages rotating over the device set) drives the coded runtime: it
     must complete 100% of requests with tokens IDENTICAL to the
     fault-free run and zero beyond-budget failures (CDC recovers every
     erasure in-step). The uncoded baseline under the same trace survives
     only via the 2MR requeue path — every outage costs requeued work.
  2. ``parity_cost`` — the paper's §6.3/Fig. 17 economics as a sweep over
     device count N: CDC covers a whole coded layer with r extra parity
     devices (CONSTANT in N) while 2MR duplicates every device (LINEAR),
     cross-checked with the adaptive planner's required budget at a fixed
     per-device unavailability.
  3. ``adaptive`` — one run through calm -> fault-storm -> calm phases:
     the adaptive redundancy planner must RAISE r when concurrent
     failures exceed the current budget and LOWER it again after the
     storm (cooldown), with every request still completing.

Run:  PYTHONPATH=src python benchmarks/chaos_resilience.py --smoke
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core.failure import StragglerModel, coverage_2mr
from repro.faults import (AdaptiveRedundancyPlanner, InjectedLatency,
                          LatencySpec, PlannerConfig, TraceInjector,
                          attach_chaos, attach_planner, churn_trace,
                          required_budget)
from repro.models import TPCtx, build
from repro.runtime import (ContinuousBatchingScheduler, RuntimeConfig,
                           ShardHealthController, run_arrivals)
from repro.serve import ModelStepper

DEFAULTS = dict(tp=4, code_r=2, n_slots=4, prompt_len=8, gen_tokens=6,
                n_requests=12, seed=0)


def _build_stepper(cfg, tp: int, code_r: int, coded: bool, max_len: int):
    ctx = TPCtx(tp=tp, mode="coded" if coded else "plain", code_r=code_r,
                moe_capacity=0)
    model = build(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    return ModelStepper(model, params, max_len=max_len)


def _workload(cfg, n_requests: int, prompt_len: int, gen_tokens: int,
              span_ms: float, seed: int):
    rng = np.random.default_rng(seed)
    gaps = span_ms / max(n_requests, 1)
    return [(i * gaps, rng.integers(0, cfg.vocab, prompt_len), gen_tokens)
            for i in range(n_requests)]


def _run(stepper, workload, trace, *, seed: int, adapt: bool = False,
         plan_window_ms: float = 200.0, max_budget: int = 2,
         perf: bool = False) -> dict:
    injector = TraceInjector(trace, stepper.n_shards) if trace else None
    latency = InjectedLatency(LatencySpec(), injector, seed=seed) \
        if injector is not None else None
    health = ShardHealthController(stepper.n_shards, stepper.erasure_budget)
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=DEFAULTS["n_slots"],
                               straggler=StragglerModel(), seed=seed,
                               perf=perf),
        health=health, latency=latency)
    if injector is not None:
        attach_chaos(sched, injector)
    if adapt:
        planner = AdaptiveRedundancyPlanner(
            PlannerConfig(window_ms=plan_window_ms, max_budget=max_budget),
            stepper.n_shards, layout=stepper.model.ctx.code_layout)
        attach_planner(sched, planner)
    completed = run_arrivals(sched, workload)
    snap = sched.metrics.snapshot()
    perf_summary = None
    if sched.executor is not None and sched.executor.perf is not None:
        perf_summary = sched.executor.perf.summary(
            snap["round_latency_measured"].get("p50_ms"))
    slo = None
    if sched.spans is not None:
        from repro.obs.slo import summarize
        slo = summarize(sched.spans)
    return {
        "slo": slo,
        "completed_all": (snap["counters"]["requests_completed"]
                          == snap["counters"]["requests_submitted"]
                          == len(workload)),
        "perf": perf_summary,
        "tokens": {r.rid: list(r.tokens) for r in completed},
        "counters": snap["counters"],
        "planner": snap["planner"],
        "elapsed_ms": snap["elapsed_ms"],
        "request_latency": snap["request_latency"],
        "ttft": snap["ttft"],
        # per-shard health timeline: exact unavailability duty cycles the
        # planner's per-round sampling approximates
        "shard_timeline": sched.shardlog.snapshot(sched.clock.now()),
    }


# ------------------------------------------------------------- sections ----

def _slo_inflation(clean: dict | None, faulty: dict | None) -> dict | None:
    """Faulty-over-fault-free tail ratios from two span-derived SLO
    summaries (``repro.obs.slo.summarize``), plus the faulty run's p99
    fault-recovery sim-ms — the span tree's direct answer to "how much of
    the tail is the faults' fault"."""
    if not clean or not faulty:
        return None

    def ratio(key):
        c, f = clean.get(key), faulty.get(key)
        if c is None or f is None or c <= 0:
            return None
        return f / c

    return {
        "ttft_p99_inflation": ratio("ttft_p99_ms"),
        "tpot_p99_inflation": ratio("tpot_p99_ms"),
        "fault_recovery_p99_ms":
            faulty["decomp"]["fault_recovery"]["p99_ms"],
        "n_missed_faulty": faulty["n_missed"],
        "miss_by_cause_faulty": faulty["miss_by_cause"],
    }


def churn_section(cfg, args) -> dict:
    """In-budget churn: coded completes everything with identical tokens;
    uncoded survives the same trace only through 2MR requeues."""
    max_len = args.prompt_len + args.gen_tokens + 8
    span = 1200.0
    workload = _workload(cfg, args.n_requests, args.prompt_len,
                         args.gen_tokens, span, args.seed)
    trace = churn_trace(args.tp, 100.0, span, period_ms=300.0,
                        down_ms=120.0, concurrent=1)

    coded = _build_stepper(cfg, args.tp, args.code_r, True, max_len)
    baseline = _run(coded, workload, None, seed=args.seed)
    # perf accounting on the headline run only: the churn trace never
    # resizes r, so attribution compiles once and stays valid
    faulty = _run(coded, workload, trace, seed=args.seed, perf=True)
    uncoded = _build_stepper(cfg, args.tp, args.code_r, False, max_len)
    uncoded_baseline = _run(uncoded, workload, None, seed=args.seed)
    uncoded_faulty = _run(uncoded, workload, trace, seed=args.seed)

    out = {
        "trace_events": len(trace),
        "coded": {k: faulty[k] for k in
                  ("completed_all", "counters", "request_latency",
                   "ttft", "shard_timeline", "perf", "slo")},
        "coded_tokens_match_fault_free":
            faulty["tokens"] == baseline["tokens"],
        "uncoded": {k: uncoded_faulty[k] for k in
                    ("completed_all", "counters", "request_latency",
                     "slo")},
        # headline: fault-attributed tail inflation, coded vs uncoded —
        # faulty-run TTFT/TPOT p99 over the same stepper's fault-free
        # run, plus the p99 sim-ms each request spent in fault recovery.
        # CDC absorbs in-budget erasures in-step, so the coded row should
        # stay near 1.0 while the uncoded row pays the 2MR requeue tax.
        "slo_inflation": {
            "coded": _slo_inflation(baseline["slo"], faulty["slo"]),
            "uncoded": _slo_inflation(uncoded_baseline["slo"],
                                      uncoded_faulty["slo"]),
        },
    }
    assert out["coded"]["completed_all"], "coded runtime lost a request"
    assert out["coded_tokens_match_fault_free"], \
        "in-budget churn changed generated tokens"
    assert faulty["counters"]["beyond_budget_failures"] == 0
    assert uncoded_faulty["counters"]["requests_requeued"] > 0, \
        "uncoded baseline should pay the 2MR requeue path"
    return out


def parity_cost_section(device_counts, unavail: float = 0.02,
                        target: float = 0.999) -> dict:
    """CDC parity cost flat in N; 2MR linear (paper Fig. 17)."""
    rows = []
    for n in device_counts:
        cov = coverage_2mr(n, 0)
        b = required_budget(n, unavail, target, b_max=4)
        rows.append({
            "devices": n,
            "extra_cdc": cov["extra_cdc_2mr"],     # 1 parity device
            "extra_2mr": cov["extra_2mr"],         # duplicate everything
            "hw_cost_cdc": cov["hw_cost_cdc_2mr"],
            "hw_cost_2mr": cov["hw_cost_2mr"],
            "planner_budget": b,
        })
    flat = len({r["extra_cdc"] for r in rows}) == 1
    linear = all(r["extra_2mr"] == r["devices"] for r in rows)
    assert flat and linear, rows
    return {"unavailability": unavail, "target": target, "rows": rows,
            "cdc_cost_flat_in_devices": flat,
            "mr2_cost_linear_in_devices": linear}


def adaptive_section(cfg, args) -> dict:
    """Calm -> storm -> calm: the planner raises r for the storm and
    lowers it again afterwards; no request is lost."""
    max_len = args.prompt_len + args.gen_tokens + 8
    calm, storm_end, end = 800.0, 2400.0, 4200.0
    # storm: waves of 2 concurrent outages — beyond the initial r=2
    # folded budget of 1, so the planner must raise r to keep CDC coverage
    trace = churn_trace(args.tp, calm, storm_end, period_ms=300.0,
                        down_ms=120.0, concurrent=2)
    workload = _workload(cfg, 2 * args.n_requests, args.prompt_len,
                         args.gen_tokens, end - 400.0, args.seed)
    stepper = _build_stepper(cfg, args.tp, args.code_r, True, max_len)
    res = _run(stepper, workload, trace, seed=args.seed, adapt=True,
               plan_window_ms=250.0)
    series = res["planner"]["r_series"]
    rs = [r for _, r in series]
    out = {
        "phases": {"calm_until_ms": calm, "storm_until_ms": storm_end},
        "completed_all": res["completed_all"],
        "r_series": series,
        "replans": res["counters"]["replans"],
        "raised_during_storm": max(rs) > rs[0] if series else False,
        "lowered_after_storm": rs[-1] < max(rs) if series else False,
        "final_code_r": int(stepper.model.ctx.code_r),
        "max_observed_concurrent": max(
            (p["window_max_dead"] for p in res["planner"]["plans"]),
            default=0),
        "max_planned_budget": max(
            (p["budget"] for p in res["planner"]["plans"]), default=0),
        "counters": res["counters"],
        "shard_timeline": res["shard_timeline"],
    }
    assert out["completed_all"], "adaptive run lost a request"
    assert out["raised_during_storm"], f"planner never raised r: {series}"
    assert out["lowered_after_storm"], f"planner never lowered r: {series}"
    return out


# ----------------------------------------------------------------- main ----

#: keys every per-arch bench row carries (roofline-anchored attribution)
PERF_ROW_KEYS = ("model_flops", "achieved_flops_per_s",
                 "roofline_utilization", "coded_overhead_frac",
                 "parity_device_equiv")


def build_report(args) -> dict:
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    report = {
        "bench": "chaos_resilience",
        "workload": {"arch": args.arch, "smoke": args.smoke,
                     **{k: getattr(args, k) for k in DEFAULTS}},
        "churn": churn_section(cfg, args),
        "parity_cost": parity_cost_section(args.device_counts),
        "adaptive": adaptive_section(cfg, args),
    }
    # per-arch roofline attribution of the headline (coded churn) run
    perf = report["churn"]["coded"].get("perf") or {}
    report["perf"] = {args.arch: {k: perf.get(k) for k in PERF_ROW_KEYS}}
    return report


def _write_outputs(args, report: dict):
    if args.out:
        import os
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True, default=str)
    if args.bench_out:
        with open(args.bench_out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True, default=str)
    if args.history:
        from repro.obs.history import append_snapshot
        churn = report["churn"]["coded"]
        slo = churn.get("slo") or {}
        metrics = {
            "p99_latency_ms": churn["request_latency"].get("p99_ms"),
            "ttft_p99_ms": churn["ttft"].get("p99_ms"),
            # span-derived decode rate (sim ms/token): steady state + tail
            "tpot_p50_ms": slo.get("tpot_p50_ms"),
            "tpot_p99_ms": slo.get("tpot_p99_ms"),
            **report["perf"][args.arch],
        }
        snap = append_snapshot(args.history, bench="chaos_resilience",
                               arch=args.arch, metrics=metrics)
        print(f"history: appended chaos_resilience/{args.arch} "
              f"snapshot to {args.history} (sha {snap['git_sha']})")


def run() -> list[dict]:
    """benchmarks.run entry: smoke-scale rows, refreshing the committed
    ``BENCH_chaos.json`` artifact and appending one trajectory snapshot to
    ``BENCH_history.jsonl`` along the way."""
    args = _parse([])
    args.smoke = True
    rep = build_report(args)
    _write_outputs(args, rep)
    infl = rep["churn"]["slo_inflation"]
    rows = [{"section": "churn",
             "completed_all": rep["churn"]["coded"]["completed_all"],
             "tokens_match": rep["churn"]["coded_tokens_match_fault_free"],
             "uncoded_requeues":
                 rep["churn"]["uncoded"]["counters"]["requests_requeued"],
             "coded_tpot_p99_inflation":
                 (infl["coded"] or {}).get("tpot_p99_inflation"),
             "uncoded_tpot_p99_inflation":
                 (infl["uncoded"] or {}).get("tpot_p99_inflation")}]
    rows += [{"section": "parity_cost", **r}
             for r in rep["parity_cost"]["rows"]]
    rows.append({"section": "adaptive",
                 "r_series": rep["adaptive"]["r_series"],
                 "raised": rep["adaptive"]["raised_during_storm"],
                 "lowered": rep["adaptive"]["lowered_after_storm"]})
    return rows


def _parse(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true")
    for key, val in DEFAULTS.items():
        ap.add_argument(f"--{key.replace('_', '-')}", type=type(val),
                        default=val)
    ap.add_argument("--device-counts", type=int, nargs="+",
                    default=[4, 8, 12, 16])
    ap.add_argument("--out", default=None)
    ap.add_argument("--bench-out", default="BENCH_chaos.json",
                    help="headline report path ('' disables)")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="append one schema-versioned trajectory snapshot "
                         "to this JSONL file ('' disables)")
    return ap.parse_args(argv)


def main():
    args = _parse()
    report = build_report(args)
    print(json.dumps(report, indent=2, sort_keys=True, default=str))
    _write_outputs(args, report)


if __name__ == "__main__":
    main()
