"""Paper §7 / Fig. 18 (extended beyond the paper): tolerating multiple
failures with MDS parity shards.

The paper sketches partial-sum overlaps and notes full correction needs
Hamming-style codes; our Vandermonde MDS generalization recovers ANY
r-subset of erasures exactly. Reports recovery error and the hardware cost
(T+r)/T at each tolerance level — still constant-per-layer vs. the linear
cost of (r+1)-modular redundancy.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CodedDenseSpec, CodeSpec, coded_matmul, \
    make_parity_weights


def run(T=8, k=128, m=None) -> list[dict]:
    m = m or T * T * 4
    rows = []
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (16, k), jnp.float32)
    w = jax.random.normal(kw, (k, m), jnp.float32) / k ** 0.5
    ref = x @ w
    for r in (1, 2, 3, 4):
        spec = CodedDenseSpec(CodeSpec(T, r), layout="dedicated")
        w_cdc = make_parity_weights(w, spec)
        worst = 0.0
        n_pat = 0
        for dead in itertools.combinations(range(T), r):
            valid = jnp.ones(T, bool).at[jnp.asarray(dead)].set(False)
            y = coded_matmul(x, w, w_cdc, spec, valid)
            worst = max(worst, float(jnp.abs(y - ref).max()))
            n_pat += 1
            if n_pat >= 35:
                break
        rows.append({
            "T": T, "r": r, "tolerates": r,
            "hw_cost_cdc": round((T + r) / T, 3),
            "hw_cost_modular": r + 1,
            "worst_abs_err_fp32": f"{worst:.2e}",
            "patterns_checked": n_pat,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
