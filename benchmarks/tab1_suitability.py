"""Paper Table 1: distribution techniques suitable for CDC robustness.

The predicate (divides weights & output, not input) is implemented in
repro.core.policy and verified empirically here: for each split method we
attempt a coded recovery and check whether parity could have been computed
OFFLINE (input-independent) — the paper's suitability criterion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CodedDenseSpec, CodeSpec, coded_matmul,
                        make_parity_weights, suitability_table)


def _empirical_output_split() -> bool:
    """Output split: offline parity => recovery works for any input."""
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(kw, (32, 64))
    spec = CodedDenseSpec(CodeSpec(4, 1), layout="dedicated")
    w_cdc = make_parity_weights(w, spec)  # offline: no x involved
    ok = True
    for seed in range(3):  # inputs the encoder never saw
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (8, 32))
        y = coded_matmul(x, w, w_cdc, spec, jnp.ones(4, bool).at[2].set(False))
        ok &= bool(jnp.allclose(y, x @ w, atol=1e-4))
    return ok


def _empirical_input_split() -> bool:
    """Input split: partial sums share no factor — a parity device would
    need the runtime inputs (paper Eq. 13-14). We verify no input-independent
    parity weight W_p exists by showing the partial sums' relationship
    changes with the input."""
    kw = jax.random.PRNGKey(0)
    w = jax.random.normal(kw, (32, 16))
    w1, w2 = w[:16], w[16:]
    ratios = []
    for seed in range(3):
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 32))
        p1 = x[:, :16] @ w1
        p2 = x[:, 16:] @ w2
        ratios.append(float(p1[0, 0] / p2[0, 0]))
    # ratio varies with input => no static combination reproduces p1 from p2
    return np.std(ratios) > 1e-3


def run() -> list[dict]:
    rows = suitability_table()
    emp = {"output": _empirical_output_split(),
           "input": not _empirical_input_split()}
    for r in rows:
        if r["method"] in emp:
            r["empirical_suitable"] = emp[r["method"]]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
