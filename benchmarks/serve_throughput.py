"""Load generator for the coded cluster runtime (ROADMAP heavy-traffic goal).

Drives ``repro.runtime`` with a Poisson request stream while per-round
latency follows the paper's heavy-tailed shard model (``StragglerModel``,
Fig. 1): a coded round completes at the T-th of T+r shard arrivals, an
uncoded round waits for all T (§6.2). A shard erasure is injected mid-run;
the coded runtime must absorb it in-step and complete 100% of admitted
requests ("the system never loses a request"), while the uncoded baseline
pays the 2MR requeue path. Emits a JSON metrics report.

Alongside the modelled (sim-clock) numbers the report carries MEASURED
wall-clock round latency, and a per-architecture executor comparison:
the same coded workload through the batched slot executor (one jitted
dispatch per round) vs sequential per-slot stepping (n_slots
dispatches), for every slot-batched family — decoder-only (granite),
enc-dec (whisper, per-slot extras bank), and xLSTM (positionless block
state). The comparison is written to ``BENCH_serve.json`` (repo root) as
the bench trajectory seed; CI asserts batched >= sequential for all
three.

Run:  PYTHONPATH=src python benchmarks/serve_throughput.py --smoke
      PYTHONPATH=src python benchmarks/serve_throughput.py --smoke \
          --n-requests 32 --rate-rps 40 --out results/serve_throughput.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core.failure import StragglerModel
from repro.models import TPCtx, build
from repro.runtime import (ContinuousBatchingScheduler, RuntimeConfig,
                           ShardHealthController, erasure, run_arrivals)
from repro.serve import ModelStepper


def make_workload(rng: np.random.Generator, n_requests: int, rate_rps: float,
                  prompt_len: int, gen_tokens: int, cfg) -> list[tuple]:
    """Poisson arrivals: iid exponential gaps at ``rate_rps`` (sim time).
    Enc-dec configs get fresh per-request encoder frames as a 4th extras
    element (threaded into the executor's stacked extras bank)."""
    gaps_ms = rng.exponential(1e3 / rate_rps, size=n_requests)
    arrivals = np.cumsum(gaps_ms)
    out = []
    for t in arrivals:
        entry = (float(t), rng.integers(0, cfg.vocab, prompt_len),
                 gen_tokens)
        if cfg.is_encdec:
            entry += ({"frames": rng.normal(
                size=(cfg.enc_seq, cfg.d_model)).astype(np.float32)},)
        out.append(entry)
    return out


#: keys every per-arch bench row carries (roofline-anchored attribution)
PERF_ROW_KEYS = ("model_flops", "achieved_flops_per_s",
                 "roofline_utilization", "coded_overhead_frac",
                 "parity_device_equiv")


def run_mode(cfg, workload, *, coded: bool, tp: int, code_r: int,
             n_slots: int, fail_time_ms: float | None, fail_shard: int,
             straggler: StragglerModel, seed: int,
             batched: bool | None = None, stepper=None,
             use_fused: bool | str = "auto",
             collect_tokens: bool = False, perf: bool = False) -> dict:
    if stepper is None:
        ctx = TPCtx(tp=tp, mode="coded" if coded else "plain",
                    code_r=code_r, moe_capacity=0)
        model = build(cfg, ctx)
        params = model.init(jax.random.PRNGKey(0))
        max_len = max(len(w[1]) + w[2] for w in workload) + 8
        stepper = ModelStepper(model, params, max_len=max_len)
    events = [] if fail_time_ms is None else [erasure(fail_time_ms,
                                                      fail_shard)]
    health = ShardHealthController(stepper.n_shards, stepper.erasure_budget,
                                   events=events)
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=n_slots, straggler=straggler,
                               seed=seed, batched=batched,
                               use_fused=use_fused, perf=perf),
        health=health)
    t0 = time.perf_counter()
    completed = run_arrivals(sched, workload)
    wall_s = time.perf_counter() - t0
    snap = sched.metrics.snapshot()
    snap["mode"] = "coded" if coded else "uncoded"
    snap["executor"] = "sequential" if sched.executor is None else "batched"
    snap["erasure_budget"] = stepper.erasure_budget
    snap["completed_all"] = (snap["counters"]["requests_completed"]
                             == snap["counters"]["requests_submitted"]
                             == len(workload))
    snap["max_requeues_seen"] = max((r.n_requeues for r in completed),
                                    default=0)
    rounds = snap["counters"]["decode_rounds"]
    snap["wall_s"] = wall_s
    snap["rounds_per_s_wall"] = rounds / wall_s if wall_s > 0 else None
    # steady-state rate from the measured per-round latency (p50 is robust
    # to the first-round compile outlier)
    meas = snap["round_latency_measured"]
    snap["rounds_per_s"] = (1e3 / meas["p50_ms"]
                            if meas.get("p50_ms") else None)
    if sched.executor is not None and sched.executor.perf is not None:
        # achieved rates at the steady-state p50 round period (robust to
        # the first-round compile outlier)
        snap["perf"] = sched.executor.perf.summary(meas.get("p50_ms"))
    if sched.spans is not None:
        # request-level SLO decomposition (obs.slo over the span trees):
        # TTFT/TPOT percentiles with per-phase breakdown + miss causes
        from repro.obs.slo import summarize
        snap["slo"] = summarize(sched.spans)
    if collect_tokens:
        snap["tokens"] = {str(r.rid): [int(t) for t in r.tokens]
                          for r in completed}
    return snap


def executor_comparison(cfg, workload, common: dict) -> dict:
    """Same coded workload, batched executor vs sequential stepping, one
    shared stepper (identical params/compile cache baseline)."""
    ctx = TPCtx(tp=common["tp"], mode="coded", code_r=common["code_r"],
                moe_capacity=0)
    model = build(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    max_len = max(len(w[1]) + w[2] for w in workload) + 8
    stepper = ModelStepper(model, params, max_len=max_len)
    out = {}
    for name, batched in (("sequential", False), ("batched", True)):
        snap = run_mode(cfg, workload, coded=True, stepper=stepper,
                        batched=batched, perf=batched, **common)
        out[name] = {
            "rounds_per_s": snap["rounds_per_s"],
            "rounds_per_s_wall": snap["rounds_per_s_wall"],
            "wall_s": snap["wall_s"],
            "decode_rounds": snap["counters"]["decode_rounds"],
            "round_latency_measured": snap["round_latency_measured"],
            "ttft": snap["ttft"],
            "completed_all": snap["completed_all"],
        }
        if "perf" in snap:
            out[name]["perf"] = snap["perf"]
        if "slo" in snap:
            out[name]["slo"] = snap["slo"]
    seq, bat = out["sequential"], out["batched"]
    if seq["rounds_per_s"] and bat["rounds_per_s"]:
        out["batched_speedup"] = bat["rounds_per_s"] / seq["rounds_per_s"]
    # hoist the roofline attribution of the production (batched) path so
    # every per-arch row carries it at top level
    for key in PERF_ROW_KEYS:
        out[key] = bat.get("perf", {}).get(key)
    return out


def fused_body_comparison(cfg, workload, common: dict) -> dict:
    """Same coded workload through the batched executor with the FULL
    Pallas round — fused in-body coded GEMM + Eq. 12 decode-and-merge
    kernels plus the fused coded head (``use_fused=True``) — vs the
    reference round (``use_fused=False``), one shared stepper.

    ``fused_native`` records whether the kernels compiled natively (TPU)
    or ran in Pallas interpret mode: interpret regresses wall-clock by
    construction (the kernel body is unrolled per grid step), so speed
    claims only hold on the native path — but the TOKEN STREAMS must
    match everywhere, which is what CI asserts on CPU runners.
    """
    ctx = TPCtx(tp=common["tp"], mode="coded", code_r=common["code_r"],
                moe_capacity=0)
    model = build(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    max_len = max(len(w[1]) + w[2] for w in workload) + 8
    stepper = ModelStepper(model, params, max_len=max_len)
    out = {"fused_native": jax.default_backend() == "tpu"}
    toks = {}
    for name, fused in (("reference", False), ("fused", True)):
        snap = run_mode(cfg, workload, coded=True, stepper=stepper,
                        batched=True, use_fused=fused,
                        collect_tokens=True, perf=True, **common)
        toks[name] = snap.pop("tokens")
        out[name] = {
            "rounds_per_s": snap["rounds_per_s"],
            "rounds_per_s_wall": snap["rounds_per_s_wall"],
            "wall_s": snap["wall_s"],
            "decode_rounds": snap["counters"]["decode_rounds"],
            "round_latency_measured": snap["round_latency_measured"],
            "completed_all": snap["completed_all"],
        }
        if "perf" in snap:
            out[name]["perf"] = snap["perf"]
    out["tokens_match"] = toks["fused"] == toks["reference"]
    ref_rps, fus_rps = (out["reference"]["rounds_per_s"],
                        out["fused"]["rounds_per_s"])
    if ref_rps and fus_rps:
        out["fused_speedup"] = fus_rps / ref_rps
    # the Pallas custom-call cost model must agree with the reference HLO
    # dots: at r=1 (sum-parity head) fused and reference rounds do the
    # same T+1 head GEMMs, so the ratio should sit within a few percent
    variants = out["fused"].get("perf", {}).get("variants", {})
    if "fused" in variants and "reference" in variants:
        out["fused_vs_reference_flops_ratio"] = (
            variants["fused"]["flops"] / variants["reference"]["flops"])
    for key in PERF_ROW_KEYS:
        out[key] = out["fused"].get("perf", {}).get(key)
    return out


def zoo_executor_comparison(archs: list[str], smoke: bool, args,
                            common: dict) -> dict:
    """Batched-vs-sequential rows for every named architecture (each with
    its own workload; enc-dec workloads carry per-request frames)."""
    out = {}
    for arch in archs:
        acfg = get_arch(arch)
        if smoke:
            acfg = smoke_config(acfg)
        arng = np.random.default_rng(args.seed)
        wl = make_workload(arng, args.n_requests, args.rate_rps,
                           args.prompt_len, args.gen_tokens, acfg)
        out[arch] = executor_comparison(acfg, wl, common)
    return out


def append_history(path: str, arch: str, row: dict):
    """One schema-versioned trajectory snapshot for a per-arch bench row
    (``repro.obs.history``): throughput + roofline attribution + tail
    latency (TTFT/TPOT from the span-tree SLO decomposition)."""
    from repro.obs.history import append_snapshot
    slo = row.get("batched", {}).get("slo", {})
    metrics = {
        "rounds_per_s": row.get("batched", {}).get("rounds_per_s")
                        or row.get("rounds_per_s"),
        "ttft_p99_ms": row.get("batched", {}).get("ttft", {}).get("p99_ms"),
        "tpot_p50_ms": slo.get("tpot_p50_ms"),
        "tpot_p99_ms": slo.get("tpot_p99_ms"),
        **{k: row.get(k) for k in PERF_ROW_KEYS},
    }
    return append_snapshot(path, bench="serve_throughput", arch=arch,
                           metrics=metrics)


def run() -> list[dict]:
    """``benchmarks.run --all`` entry: smoke-scale coded vs uncoded rows
    (Poisson load, mid-run erasure, coded must complete 100%), then a
    refresh of the committed artifacts — ``BENCH_serve.json`` plus one
    ``BENCH_history.jsonl`` snapshot per arch — so one command regenerates
    the whole serving trajectory."""
    cfg = smoke_config(get_arch("granite-3-8b"))
    rng = np.random.default_rng(0)
    workload = make_workload(rng, 8, 25.0, 8, 4, cfg)
    common = dict(tp=4, code_r=2, n_slots=4,
                  fail_time_ms=workload[len(workload) // 2][0],
                  fail_shard=1, straggler=StragglerModel(), seed=0)
    rows = []
    for coded in (True, False):
        snap = run_mode(cfg, workload, coded=coded, **common)
        rows.append({
            "mode": snap["mode"],
            "executor": snap["executor"],
            "completed_all": snap["completed_all"],
            "requests_requeued": snap["counters"]["requests_requeued"],
            "p99_latency_ms": snap["request_latency"].get("p99_ms"),
            "p50_ttft_ms": snap["ttft"].get("p50_ms"),
            "p99_ttft_ms": snap["ttft"].get("p99_ms"),
            "rounds_per_s": snap["rounds_per_s"],
        })
    assert rows[0]["completed_all"], "coded runtime lost a request"
    # r=1 so the fused head (sum parity, T+1 GEMMs) matches the reference
    # round's FLOPs — the 5% agreement the artifact is asserted against
    main(["--smoke", "--n-requests", "8", "--gen-tokens", "4",
          "--code-r", "1", "--fused-body", "--skip-uncoded", "--quiet"])
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--code-r", type=int, default=2)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--rate-rps", type=float, default=25.0,
                    help="Poisson arrival rate, requests per sim-second")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-tokens", type=int, default=8)
    ap.add_argument("--fail-time-ms", type=float, default=None,
                    help="erasure injection time; default: mid-workload")
    ap.add_argument("--fail-shard", type=int, default=1)
    ap.add_argument("--no-failure", action="store_true")
    ap.add_argument("--skip-uncoded", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write JSON report here")
    ap.add_argument("--bench-out", default="BENCH_serve.json",
                    help="batched-vs-sequential bench report path "
                         "('' disables)")
    ap.add_argument("--skip-executor-compare", action="store_true")
    ap.add_argument("--fused-body", action="store_true",
                    help="add the fused-vs-reference round comparison "
                         "(full-Pallas decode round) to the report and "
                         "BENCH_serve.json")
    ap.add_argument("--compare-archs",
                    default="granite-3-8b,whisper-medium,xlstm-125m",
                    help="comma-separated archs for the per-architecture "
                         "batched-vs-sequential comparison (every slot-"
                         "batched family rides the same executor)")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="append one schema-versioned trajectory snapshot "
                         "per compared arch to this JSONL file "
                         "('' disables); gate with "
                         "`python -m repro.obs.history check`")
    ap.add_argument("--quiet", action="store_true",
                    help="skip printing the full JSON report")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    rng = np.random.default_rng(args.seed)
    workload = make_workload(rng, args.n_requests, args.rate_rps,
                             args.prompt_len, args.gen_tokens, cfg)
    fail_time = None
    if not args.no_failure:
        fail_time = (args.fail_time_ms if args.fail_time_ms is not None
                     else workload[len(workload) // 2][0])
    straggler = StragglerModel()
    common = dict(tp=args.tp, code_r=args.code_r, n_slots=args.n_slots,
                  fail_time_ms=fail_time, fail_shard=args.fail_shard,
                  straggler=straggler, seed=args.seed)

    report = {
        "workload": {
            "arch": args.arch, "smoke": args.smoke,
            "n_requests": args.n_requests, "rate_rps": args.rate_rps,
            "prompt_len": args.prompt_len, "gen_tokens": args.gen_tokens,
            "fail_time_ms": fail_time, "fail_shard": args.fail_shard,
            "tp": args.tp, "code_r": args.code_r, "n_slots": args.n_slots,
        },
        "coded": run_mode(cfg, workload, coded=True, **common),
    }
    if not args.skip_uncoded:
        report["uncoded"] = run_mode(cfg, workload, coded=False, **common)
        c, u = report["coded"], report["uncoded"]
        if u["request_latency"].get("p99_ms"):
            report["p99_improvement_pct"] = 100 * (
                1 - c["request_latency"]["p99_ms"]
                / u["request_latency"]["p99_ms"])
    if not args.skip_executor_compare:
        archs = [a.strip() for a in args.compare_archs.split(",")
                 if a.strip()]
        report["executor_comparison"] = zoo_executor_comparison(
            archs, args.smoke, args, common)
    if args.fused_body:
        report["fused_body_comparison"] = fused_body_comparison(
            cfg, workload, common)

    if not args.quiet:
        print(json.dumps(report, indent=2, sort_keys=True))
    if args.out:
        import os
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
    if args.bench_out and ("executor_comparison" in report
                           or "fused_body_comparison" in report):
        bench = {
            "bench": "serve_throughput",
            "workload": report["workload"],
        }
        for key in ("executor_comparison", "fused_body_comparison"):
            if key in report:
                bench[key] = report[key]
        with open(args.bench_out, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
    if args.history:
        for arch, row in report.get("executor_comparison", {}).items():
            snap = append_history(args.history, arch, row)
            print(f"history: appended serve_throughput/{arch} "
                  f"snapshot to {args.history} (sha {snap['git_sha']})")
    if not report["coded"]["completed_all"]:
        raise SystemExit("coded runtime lost requests — this violates the "
                         "paper's continuity claim")


if __name__ == "__main__":
    main()
