"""Paper Fig. 11-12 (Case Study I/II): recovery latency with & without CDC.

The paper's AlexNet system splits a 2048-wide fc layer across two devices;
when one fails, the vanilla system must (detect +) reload the missing
weights and recompute that half on a surviving device — measured 2.4x
slowdown. With CDC the recovery is a local subtract fused into the combine.

Here we measure, on CPU, per-request wall time of:
  intact         : output-split matmul, all shards alive
  vanilla-recover: failure => recompute the missing shard's GEMM (the
                   "load new weights + redo multiplications" path)
  cdc-recover    : failure => parity decode (paper Eq. 12), no recompute
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import CodedDenseSpec, CodeSpec, coded_matmul, \
    make_parity_weights


def _time(f, *args, n=30):
    f(*args)  # compile+warm
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def run(batch=64, k=4096, m=2048, T=2) -> list[dict]:
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (batch, k), jnp.float32)
    w = jax.random.normal(kw, (k, m), jnp.float32) / k ** 0.5
    spec = CodedDenseSpec(CodeSpec(T, 1), layout="dedicated")
    w_cdc = make_parity_weights(w, spec)
    valid_all = jnp.ones(T, bool)
    valid_dead = valid_all.at[0].set(False)

    @jax.jit
    def intact(x):
        return coded_matmul(x, w, None, spec)

    @jax.jit
    def vanilla_recover(x):
        y = coded_matmul(x, w, None, spec)
        # recompute the dead shard from reloaded weights (the paper's
        # vanilla path; detection latency of tens of seconds not included)
        w_dead = jax.lax.dynamic_slice_in_dim(w, 0, m // T, 1)
        y_dead = x @ w_dead
        return jax.lax.dynamic_update_slice_in_dim(y, y_dead, 0, 1)

    @jax.jit
    def cdc_recover(x):
        return coded_matmul(x, w, w_cdc, spec, valid_dead)

    @jax.jit
    def cdc_intact(x):
        return coded_matmul(x, w, w_cdc, spec, valid_all)

    t_intact = _time(intact, x)
    t_vanilla = _time(vanilla_recover, x)
    t_cdc = _time(cdc_recover, x)
    t_cdc_ok = _time(cdc_intact, x)
    return [{
        "us_intact": round(t_intact, 1),
        "us_vanilla_recover": round(t_vanilla, 1),
        "us_cdc_recover": round(t_cdc, 1),
        "us_cdc_no_failure": round(t_cdc_ok, 1),
        "vanilla_slowdown_x": round(t_vanilla / t_intact, 2),
        "cdc_slowdown_x": round(t_cdc / t_intact, 2),
        "note": "paper: 2.4x slowdown after vanilla recovery; ~1x with CDC "
                "(plus tens of seconds of detection the vanilla path pays "
                "once, not modeled here)",
    }]


if __name__ == "__main__":
    for r in run():
        print(r)
