"""Render the roofline table from the dry-run results JSON (EXPERIMENTS.md
§Roofline source of truth)."""
from __future__ import annotations

import json
import os

DEFAULT = "/root/repo/results/dryrun.json"
OPT = "/root/repo/results/dryrun_opt.json"


def run(path: str = DEFAULT, opt_path: str = OPT) -> list[dict]:
    if not os.path.exists(path):
        return [{"note": f"no dry-run results at {path}; run "
                 "`python -m repro.launch.dryrun --all`"}]
    with open(path) as f:
        results = json.load(f)
    opt = {}
    if os.path.exists(opt_path):
        with open(opt_path) as f:
            opt = json.load(f)
    rows = []
    for key, rec in sorted(results.items()):
        if rec.get("status") == "skip":
            rows.append({"cell": key, "status": "skip",
                         "why": rec["why"][:60]})
            continue
        if rec.get("status") != "ok":
            rows.append({"cell": key, "status": rec.get("status")})
            continue
        rl = rec["roofline"]
        row = {
            "cell": key,
            "compute_s": f"{rl['compute_s']:.3e}",
            "memory_s": f"{rl['memory_s']:.3e}",
            "collective_s": f"{rl['collective_s']:.3e}",
            "dominant": rl["dominant"],
            "useful": f"{rl['useful_ratio']:.2f}",
            "roofline_frac": f"{rl['roofline_fraction']:.3f}",
            "compile_s": rec["compile_s"],
        }
        o = opt.get(key)
        if o and o.get("status") == "ok":
            ro = o["roofline"]
            row["opt_memory_s"] = f"{ro['memory_s']:.3e}"
            row["opt_collective_s"] = f"{ro['collective_s']:.3e}"
            row["opt_frac"] = f"{ro['roofline_fraction']:.3f}"
        rows.append(row)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
