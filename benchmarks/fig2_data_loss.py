"""Paper Fig. 2: data loss inside a layer destroys accuracy; CDC recovers it.

We train two small classifiers on a synthetic 10-class task (a LeNet-scale
MLP and a deeper/wider one, mirroring the paper's LeNet-5 vs Inception-v3
sensitivity contrast), then erase p% of the first hidden layer's output
split across T=4 devices — (a) uncoded: erased activations are zeros;
(b) CDC: the erased shard is reconstructed from the parity shard. The paper's
claim: >70% loss is destructive; CDC holds accuracy at the fault-free level.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CodedDenseSpec, CodeSpec, coded_matmul, \
    make_parity_weights


def _make_task(key, n=4096, d=64, classes=10):
    kw, kx = jax.random.split(key)
    wstar = jax.random.normal(kw, (d, classes))
    x = jax.random.normal(kx, (n, d))
    y = jnp.argmax(x @ wstar + 0.3 * jax.random.normal(kw, (n, classes)),
                   axis=-1)
    return x, y


def _train_mlp(key, x, y, hidden, classes=10, steps=300, lr=0.1):
    dims = [x.shape[1]] + hidden + [classes]
    ks = jax.random.split(key, len(dims))
    params = [(jax.random.normal(ks[i], (dims[i], dims[i + 1]))
               / np.sqrt(dims[i]), jnp.zeros(dims[i + 1]))
              for i in range(len(dims) - 1)]

    def fwd(params, x):
        h = x
        for w, b in params[:-1]:
            h = jax.nn.relu(h @ w + b)
        w, b = params[-1]
        return h @ w + b

    def loss(params):
        lg = fwd(params, x)
        return -jnp.take_along_axis(jax.nn.log_softmax(lg), y[:, None],
                                    1).mean()

    @jax.jit
    def step(params):
        g = jax.grad(loss)(params)
        return jax.tree.map(lambda p, g: p - lr * g, params, g)

    for _ in range(steps):
        params = step(params)
    return params, fwd


def _acc_with_loss(params, x, y, T, frac_lost, coded, key):
    """Evaluate with `frac_lost` of the first hidden layer erased."""
    (w1, b1), rest = params[0], params[1:]
    spec = CodedDenseSpec(CodeSpec(T, 1), layout="dedicated")
    w_cdc = make_parity_weights(w1, spec)
    m = w1.shape[1]
    n_lost = int(frac_lost * T)
    valid = jnp.ones(T, bool)
    if n_lost:
        dead = jax.random.choice(key, T, (min(n_lost, T - 1),),
                                 replace=False)
        valid = valid.at[dead].set(False)
    if coded:
        h = coded_matmul(x, w1, w_cdc, spec, valid) + b1
    else:
        # uncoded: the lost shard's outputs are simply zero (paper Fig. 2)
        h = coded_matmul(x, w1, None, spec) + 0.0
        mask = jnp.repeat(valid, m // T)
        h = h * mask[None, :] + 0.0
        h = h + b1 * mask[None, :]
    h = jax.nn.relu(h)
    for w, b in rest[:-1]:
        h = jax.nn.relu(h @ w + b)
    w, b = rest[-1]
    pred = jnp.argmax(h @ w + b, -1)
    return float((pred == y).mean())


def run() -> list[dict]:
    key = jax.random.PRNGKey(0)
    x, y = _make_task(key)
    rows = []
    for name, hidden in [("mlp-lenet-scale", [128, 64]),
                         ("mlp-deep", [256, 256, 128, 64])]:
        params, _ = _train_mlp(jax.random.PRNGKey(1), x, y, hidden)
        T = 4
        base = _acc_with_loss(params, x, y, T, 0.0, False,
                              jax.random.PRNGKey(2))
        for frac in (0.25, 0.5, 0.75):
            a_plain = _acc_with_loss(params, x, y, T, frac, False,
                                     jax.random.PRNGKey(3))
            a_cdc = _acc_with_loss(params, x, y, T, 0.25, True,
                                   jax.random.PRNGKey(3))
            rows.append({
                "model": name, "loss_frac": frac,
                "acc_intact": base, "acc_uncoded": a_plain,
                "acc_cdc_one_shard_lost": a_cdc,
                "drop_uncoded": base - a_plain,
                "drop_cdc": base - a_cdc,
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
