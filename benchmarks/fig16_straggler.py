"""Paper Fig. 14-16: straggler mitigation via the coded spare shard.

The paper's four-RPi WiFi system (Fig. 1) shows heavy-tailed arrivals: 34%
of shard responses land after 2x the 50 ms compute floor. With one parity
device, a request completes after the FASTEST T of T+1 responses. The paper
reports up to 35% performance improvement as device count grows (Fig. 16b).
"""
from __future__ import annotations

from repro.core.failure import StragglerModel, mitigation_improvement


def run() -> list[dict]:
    model = StragglerModel(floor_ms=50.0, mu=3.0, sigma=1.0)
    rows = []
    for n in (2, 3, 4, 6, 8, 10, 12):
        rows.append(mitigation_improvement(model, n, n_parity=1))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
