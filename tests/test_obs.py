"""Flight recorder, shard timelines, and exporters (``repro.obs``).

Tier-1 properties: the recorder is deterministic under SimClock replay
(a seeded chaos run traced twice yields identical event streams modulo
wall-clock fields), instants are monotone in sim time and spans are
non-negative, the Chrome/Perfetto export validates and round-trips
through JSON with every injected fault linked to its resolution, the
per-shard duty cycles agree with the live ``ShardHealthController``
mask, the bounded metrics keep their snapshot schema (and reject unknown
counter names), and a scheduler WITHOUT a tracer records zero events
through a no-op whose ``emit`` is never even called.
"""
import json
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.faults import (AdaptiveRedundancyPlanner, ChaosSpec,
                          FaultInjector, PlannerConfig, TraceInjector,
                          attach_chaos, attach_planner, churn_trace)
from repro.models import TPCtx, build
from repro.obs import (EVENT_KINDS, NULL_RECORDER, FlightRecorder,
                       MetricsServer, ShardTimeline, chrome_trace,
                       prometheus_text, validate_chrome_trace,
                       write_chrome_trace)
from repro.obs.tracer import _NullRecorder
from repro.runtime import (ContinuousBatchingScheduler, HealthAction,
                           RuntimeConfig, ShardHealthController, SimClock,
                           erasure, recovery, run_arrivals)
from repro.runtime.metrics import Histogram, RuntimeMetrics
from repro.serve import ModelStepper

GEN = 6
PROMPT_LEN = 8


def _fresh_stepper(code_r=2, tp=4):
    cfg = smoke_config(get_arch("granite-3-8b"))
    model = build(cfg, TPCtx(tp=tp, mode="coded", code_r=code_r,
                             moe_capacity=0))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ModelStepper(model, params, max_len=48)


def _workload(cfg, n, span_ms=400.0):
    rng = np.random.default_rng(7)
    gap = span_ms / max(n, 1)
    return [(i * gap, rng.integers(0, cfg.vocab, PROMPT_LEN), GEN)
            for i in range(n)]


def _chaos_run(tracer, seed=0, n_requests=6):
    """One seeded churn run with a tracer; returns (sched, completed)."""
    cfg, stepper = _fresh_stepper()
    injector = FaultInjector(
        ChaosSpec(mtbf_ms=120.0, mttr_ms=30.0), stepper.n_shards, seed=seed)
    health = ShardHealthController(stepper.n_shards, stepper.erasure_budget)
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=2, step_time_ms=10.0, seed=seed),
        health=health, tracer=tracer)
    attach_chaos(sched, injector)
    completed = run_arrivals(sched, _workload(cfg, n_requests))
    return sched, completed


# ----------------------------------------------------------- recorder ----

def test_emit_rejects_unknown_kind():
    rec = FlightRecorder()
    with pytest.raises(ValueError, match="unknown trace event kind"):
        rec.emit("request.submitt", rid=0)


def test_ring_buffer_bounds_memory():
    rec = FlightRecorder(capacity=8)
    for i in range(20):
        rec.emit("round.dispatch", track="rounds", t_ms=float(i), round=i)
    assert len(rec) == 8
    assert rec.n_emitted == 20
    assert rec.dropped == 12
    # the OLDEST events were dropped
    assert [e.args["round"] for e in rec.events()] == list(range(12, 20))


def test_comparable_excludes_wall_fields():
    a, b = FlightRecorder(), FlightRecorder()
    for rec in (a, b):
        rec.emit("round.harvest", track="rounds", t_ms=1.0,
                 wall_dur_ms=float(np.random.default_rng().random()),
                 wall_args={"block_ms": float(id(rec))}, n_harvested=2)
    assert a.comparable() == b.comparable()
    assert a.events()[0].wall_args != b.events()[0].wall_args


def test_emit_stamps_with_bound_sim_clock():
    clock = SimClock()
    rec = FlightRecorder(clock=clock)
    clock.advance(42.0)
    ev = rec.emit("code.reencode", track="rounds", r=2)
    assert ev.t_ms == 42.0
    # bind_clock adopts only when unbound
    rec.bind_clock(SimClock())
    assert rec.clock is clock


def test_null_recorder_is_inert():
    assert NULL_RECORDER.enabled is False
    assert NULL_RECORDER.emit("request.submit", rid=0) is None
    assert len(NULL_RECORDER) == 0
    NULL_RECORDER.bind_clock(SimClock())     # shared singleton: never binds
    assert NULL_RECORDER.clock is None


def test_untraced_scheduler_never_calls_emit(monkeypatch):
    """The disabled fast path is ONE branch: call sites guard on
    ``tracer.enabled`` and must not even call ``emit`` (the <=1%-overhead
    contract for tracing-off runs)."""
    def boom(self, *a, **kw):
        raise AssertionError("emit() called on a disabled recorder")
    monkeypatch.setattr(_NullRecorder, "emit", boom)
    sched, completed = _chaos_run(tracer=None, seed=1, n_requests=3)
    assert sched.tracer is NULL_RECORDER
    assert len(completed) == 3
    assert len(NULL_RECORDER) == 0


# ----------------------------------------------- deterministic replay ----

@pytest.fixture(scope="module")
def traced_pair():
    rec_a, rec_b = FlightRecorder(), FlightRecorder()
    sched_a, _ = _chaos_run(rec_a, seed=3)
    sched_b, _ = _chaos_run(rec_b, seed=3)
    return rec_a, sched_a, rec_b, sched_b


def test_chaos_replay_identical_event_stream(traced_pair):
    rec_a, sched_a, rec_b, sched_b = traced_pair
    assert len(rec_a) > 0
    assert rec_a.comparable() == rec_b.comparable()
    snap_a, snap_b = sched_a.metrics.snapshot(), sched_b.metrics.snapshot()
    # the MEASURED wall-clock round series is real-hardware timing, the
    # one intentionally nondeterministic surface; everything else replays
    snap_a.pop("round_latency_measured")
    snap_b.pop("round_latency_measured")
    assert snap_a == snap_b


def test_instants_monotone_and_spans_nonnegative(traced_pair):
    """Per (track, kind) the sim stamps are non-decreasing (fault events
    carry their SCHEDULED sim time, so streams interleave across kinds at
    a round boundary — but each stream is time-ordered), no stamp is in
    the future of the round that emitted it, and spans are well-formed."""
    rec, sched = traced_pair[0], traced_pair[1]
    last: dict = {}
    for e in rec.events():
        assert e.dur_ms >= 0.0 and e.wall_dur_ms >= 0.0
        assert e.kind in EVENT_KINDS
        assert e.t_ms <= sched.clock.now()
        if e.dur_ms == 0.0:      # spans backfill their start time
            key = (e.track, e.kind)
            assert e.t_ms >= last.get(key, -np.inf), key
            last[key] = e.t_ms


def test_request_lifecycle_accounting(traced_pair):
    rec, sched = traced_pair[0], traced_pair[1]
    c = sched.metrics.counters
    assert len(rec.by_kind("request.submit")) == c["requests_submitted"]
    assert len(rec.by_kind("request.admit")) == c["requests_admitted"]
    assert len(rec.by_kind("request.complete")) == c["requests_completed"]
    assert len(rec.by_kind("fault.inject")) == c["faults_injected"]
    assert len(rec.by_kind("fault.recovered")) == c["erasures_recovered"]
    for e in rec.by_kind("request.complete"):
        assert 0.0 <= e.args["ttft_ms"] <= e.args["latency_ms"]
    # TTFT distribution observed for every completion
    assert sched.metrics.ttft_ms.n == c["requests_completed"]


# ------------------------------------------------------- chrome export ----

def test_chrome_trace_validates_and_roundtrips(tmp_path, traced_pair):
    rec, sched = traced_pair[0], traced_pair[1]
    path = tmp_path / "run.trace.json"
    trace = write_chrome_trace(str(path), rec, sched.shardlog,
                               now_ms=sched.clock.now())
    loaded = json.loads(path.read_text())
    assert loaded == trace
    stats = validate_chrome_trace(loaded, require_fault_links=True)
    assert stats["n_injected_erasures"] > 0
    assert stats["n_linked"] == stats["n_injected_erasures"]
    # exported events = recorder buffer + one "down" slice per interval
    assert stats["n_events"] == len(rec) + \
        len(sched.shardlog.all_intervals(sched.clock.now()))
    names = {e["args"]["name"] for e in loaded["traceEvents"]
             if e.get("name") == "thread_name"}
    assert {"requests", "rounds"} <= names
    assert any(n.startswith("shard:") for n in names)
    assert any(n.startswith("slot:") for n in names)


def test_validator_rejects_unresolved_fault():
    rec = FlightRecorder(clock=SimClock())
    rec.emit("fault.inject", track="shard:0", t_ms=5.0, fault="erasure",
             shard=0)
    with pytest.raises(ValueError, match="no recovery"):
        validate_chrome_trace(chrome_trace(rec))
    rec.emit("fault.recovered", track="shard:0", t_ms=5.0, shard=0,
             n_dead=1, budget=1)
    assert validate_chrome_trace(chrome_trace(rec))["n_linked"] == 1


def test_beyond_budget_chain_links_and_traces():
    """Two concurrent erasures beat the r=2 folded budget of 1: the trace
    must carry the full 2MR chain (beyond_budget -> requeue -> heal_all ->
    reencode) and still validate."""
    cfg, stepper = _fresh_stepper()
    trace = [{"t_ms": 30.0, "kind": "erasure", "shard": 0},
             {"t_ms": 30.0, "kind": "erasure", "shard": 1}]
    injector = TraceInjector(trace, stepper.n_shards)
    health = ShardHealthController(stepper.n_shards, stepper.erasure_budget)
    rec = FlightRecorder()
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=2, step_time_ms=10.0),
        health=health, tracer=rec)
    attach_chaos(sched, injector)
    completed = run_arrivals(sched, _workload(cfg, 4, span_ms=100.0))
    assert len(completed) == 4
    assert len(rec.by_kind("fault.beyond_budget")) == 1
    assert len(rec.by_kind("shard.heal_all")) == 1
    assert len(rec.by_kind("request.requeue")) >= 1
    assert len(rec.by_kind("code.reencode")) >= 1
    stats = validate_chrome_trace(
        chrome_trace(rec, sched.shardlog, now_ms=sched.clock.now()),
        require_fault_links=True)
    assert stats["n_linked"] == stats["n_injected_erasures"] == 2


def test_planner_decisions_and_resize_traced():
    cfg, stepper = _fresh_stepper()
    trace = churn_trace(stepper.n_shards, 60.0, 600.0, period_ms=150.0,
                        down_ms=60.0, concurrent=2)
    injector = TraceInjector(trace, stepper.n_shards)
    health = ShardHealthController(stepper.n_shards, stepper.erasure_budget)
    rec = FlightRecorder()
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=2, step_time_ms=10.0),
        health=health, tracer=rec)
    attach_chaos(sched, injector)
    attach_planner(sched, AdaptiveRedundancyPlanner(
        PlannerConfig(window_ms=100.0), stepper.n_shards,
        layout=stepper.model.ctx.code_layout))
    run_arrivals(sched, _workload(cfg, 8, span_ms=700.0))
    plans = rec.by_kind("planner.plan")
    assert len(plans) == len(sched.metrics.plan_log)
    assert all(e.track == "planner" for e in plans)
    assert all({"budget", "r", "applied", "reason"} <= set(e.args)
               for e in plans)
    # the storm forces a replan: the stepper (adopted by the scheduler)
    # must surface the geometry change as code.resize
    assert sched.metrics.counters["replans"] >= 1
    resizes = rec.by_kind("code.resize")
    assert len(resizes) >= 1
    assert resizes[0].args["r_new"] != resizes[0].args["r_old"]
    validate_chrome_trace(
        chrome_trace(rec, sched.shardlog, now_ms=sched.clock.now()),
        require_fault_links=True)


# ------------------------------------------------------ shard timeline ----

def test_shard_timeline_matches_controller():
    health = ShardHealthController(4, budget=2)
    tl = ShardTimeline(4, t0_ms=0.0)
    health.observers.append(tl)
    for ev in (erasure(10.0, 0), erasure(20.0, 2), recovery(40.0, 0),
               erasure(50.0, 0), recovery(80.0, 2)):
        health.apply(ev)
    # open interval on shard 0 only; controller mask must agree
    assert tl.down_now.tolist() == (~health.valid).tolist()
    duty = tl.duty_cycle(100.0)
    # shard 0: down [10,40) + [50,100 open) = 80ms of 100; shard 2: 60ms
    assert duty[0] == pytest.approx(0.8)
    assert duty[2] == pytest.approx(0.6)
    assert duty[1] == duty[3] == 0.0
    assert tl.erasures.tolist() == [2, 0, 1, 0]
    assert tl.recoveries.tolist() == [1, 0, 1, 0]
    snap = tl.snapshot(100.0)
    assert snap["total_erasures"] == 3
    assert snap["shards"][0]["down_now"] is True
    assert snap["max_duty_cycle"] == pytest.approx(0.8)
    ivs = tl.all_intervals(100.0)
    assert (0, 50.0, 100.0, "open") in ivs
    assert (2, 20.0, 80.0, "recovery") in ivs


def test_shard_timeline_replica_swap_heals_everything():
    health = ShardHealthController(4, budget=1)
    tl = ShardTimeline(4)
    health.observers.append(tl)
    health.apply(erasure(5.0, 1))
    health.apply(erasure(7.0, 3))               # beyond budget
    assert health.replace_replica(9.0) == 2     # 2MR swap
    assert not tl.down_now.any()
    assert health.valid.all()
    assert tl.replica_heals.tolist() == [0, 1, 0, 1]
    assert tl.downtime_ms[1] == pytest.approx(4.0)
    assert tl.downtime_ms[3] == pytest.approx(2.0)
    # duplicate erasure reports apply as NOOP and leave the timeline alone
    health.apply(erasure(10.0, 1))
    health.apply(erasure(11.0, 1))
    assert health.log[-1][1] is HealthAction.NOOP
    assert tl.erasures[1] == 2


def test_scheduler_shardlog_live_consistency(traced_pair):
    sched = traced_pair[1]
    tl = sched.shardlog
    assert tl.down_now.tolist() == (~sched.health.valid).tolist()
    duty = tl.duty_cycle(sched.clock.now())
    assert np.all((0.0 <= duty) & (duty <= 1.0))
    assert int(tl.erasures.sum()) >= \
        sched.metrics.counters["erasures_recovered"]


# ------------------------------------------------------ bounded metrics ----

def test_histogram_exact_until_reservoir_then_bounded():
    h = Histogram(reservoir_size=64, seed=0)
    xs = np.arange(1.0, 51.0)
    for x in xs:
        h.observe(x)
    assert len(h) == 50
    assert h.percentile(50) == pytest.approx(np.percentile(xs, 50))
    assert h.percentile(99) == pytest.approx(np.percentile(xs, 99))
    assert h.dist()["max_ms"] == 50.0
    for x in np.arange(51.0, 1001.0):        # push past the reservoir
        h.observe(x)
    assert h.n == 1000
    assert h._res.size == 64                 # memory stays bounded
    assert h.dist()["n"] == 1000
    assert h.dist()["max_ms"] == 1000.0
    assert h.mean == pytest.approx(np.arange(1.0, 1001.0).mean())
    les, counts = zip(*h.buckets())
    assert les[-1] == float("inf") and counts[-1] == 1000
    assert all(a <= b for a, b in zip(counts, counts[1:]))  # cumulative


def test_histogram_reservoir_is_deterministic():
    a, b = Histogram(reservoir_size=32, seed=5), \
        Histogram(reservoir_size=32, seed=5)
    for x in np.random.default_rng(0).exponential(10.0, 500):
        a.observe(x)
        b.observe(x)
    assert a.percentile(50) == b.percentile(50)
    assert a.dist() == b.dist()


def test_metrics_unknown_counter_raises():
    m = RuntimeMetrics()
    with pytest.raises(KeyError, match="unknown counter"):
        m.count("requests_complete")         # the old silent-typo bug
    m.register("custom_events")
    m.count("custom_events", 3)
    assert m.counters["custom_events"] == 3
    m.register("custom_events")              # re-register: no reset
    assert m.counters["custom_events"] == 3


def test_snapshot_schema_unchanged():
    m = RuntimeMetrics()
    m.mark(0.0)
    m.observe_request(10.0, 2.0, ttft_ms=3.0)
    m.observe_round_ms(1.5)
    m.sample_queue_depth(1.0, 4)
    m.mark(5.0)
    snap = m.snapshot()
    for key in ("counters", "elapsed_ms", "throughput", "request_latency",
                "queueing_delay", "ttft", "round_latency_measured",
                "queue_depth", "planner"):
        assert key in snap
    assert set(snap["request_latency"]) == \
        {"n", "mean_ms", "p50_ms", "p99_ms", "max_ms"}
    assert snap["ttft"]["p50_ms"] == 3.0
    assert json.loads(m.to_json())           # JSON-serialisable


# -------------------------------------------------- prometheus + server ----

def test_prometheus_text_exposition(traced_pair):
    rec, sched = traced_pair[0], traced_pair[1]
    text = prometheus_text(sched.metrics, sched.shardlog,
                           sched.clock.now(), rec)
    assert 'repro_runtime_counter{name="requests_completed"}' in text
    assert 'repro_request_ttft_ms_bucket{le="+Inf"}' in text
    assert "repro_request_latency_ms_sum" in text
    assert 'repro_shard_unavailability{shard="0"}' in text
    assert f"repro_trace_events_total {rec.n_emitted}" in text
    # every histogram's +Inf bucket equals its count
    for line in text.splitlines():
        if line.startswith("repro_request_latency_ms_count"):
            assert line.split()[-1] == str(sched.metrics.latencies_ms.n)


def test_metrics_server_serves_metrics_and_trace(traced_pair):
    rec, sched = traced_pair[0], traced_pair[1]
    server = MetricsServer(sched.metrics, sched.shardlog, rec,
                           sched.clock, port=0).start()
    try:
        base = f"http://127.0.0.1:{server.port}"
        with urllib.request.urlopen(f"{base}/metrics", timeout=10) as r:
            assert r.status == 200
            assert b"repro_runtime_counter" in r.read()
        with urllib.request.urlopen(f"{base}/trace", timeout=10) as r:
            trace = json.loads(r.read())
        validate_chrome_trace(trace, require_fault_links=True)
    finally:
        server.stop()
