"""Integration: serving with mid-request failure injection; training loop
with checkpoint/restart; the paper's operational guarantees end-to-end."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.data import DataConfig
from repro.models import TPCtx, build
from repro.optim import AdamWConfig
from repro.serve import ServeConfig, ServingEngine
from repro.train import Trainer, TrainerConfig, TrainConfig


def _engine(coded=True):
    cfg = smoke_config(get_arch("granite-3-8b"))
    ctx = TPCtx(tp=4, mode="coded" if coded else "plain", code_r=2,
                moe_capacity=0)
    m = build(cfg, ctx)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, ServeConfig(max_len=64, batch=2,
                                               cache_dtype=jnp.float32))
    batch = m.dummy_batch(jax.random.PRNGKey(1), 2, 8)
    return eng, batch


def test_generation_survives_midrequest_failure():
    """Case Study II (Fig. 13): a failure mid-generation changes NOTHING —
    same tokens, no re-dispatch, no slowdown path."""
    eng, batch = _engine(coded=True)
    toks_ok = eng.generate(batch, 6)
    eng2, _ = _engine(coded=True)
    toks_fail = eng2.generate(batch, 6, fail_at={2: 1})  # kill shard 1
    np.testing.assert_array_equal(toks_ok, toks_fail)
    assert eng2.metrics["erasures_recovered"] == 1


def test_straggler_latency_model():
    from repro.core.failure import StragglerModel
    eng, _ = _engine(coded=True)
    stats = eng.straggler_latency(StragglerModel(), n_trials=2000)
    # first-T-of-(T+r) is never slower in expectation
    assert stats["mean_coded_ms"] <= stats["mean_uncoded_ms"]
    assert stats["p99_coded_ms"] <= stats["p99_uncoded_ms"]


def test_trainer_loss_decreases_and_resumes(tmp_path):
    cfg = smoke_config(get_arch("h2o-danube-1.8b"))
    ctx = TPCtx()
    model = build(cfg, ctx)
    ckpt_dir = str(tmp_path / "ck")
    common = dict(
        ocfg=AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60,
                         schedule="constant", weight_decay=0.0),
        scfg=TrainConfig(microbatches=1, remat="none"),
        dcfg=DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8),
    )
    t1 = Trainer(model, TrainerConfig(steps=30, ckpt_dir=ckpt_dir,
                                      ckpt_every=15, log_every=1), **common)
    out1 = t1.run()
    losses = [l for _, l in out1["losses"]]
    head, tail = np.mean(losses[:5]), np.mean(losses[-5:])
    assert tail < head, (head, tail, losses)
    assert os.path.isdir(os.path.join(ckpt_dir, "step_00000030"))

    # resume: continues from step 30, runs to 36
    t2 = Trainer(model, TrainerConfig(steps=36, ckpt_dir=ckpt_dir,
                                      ckpt_every=100, log_every=2), **common)
    out2 = t2.run(resume=True)
    assert out2["final_step"] == 36


def test_train_through_failure():
    """CDC differentiates: training WITH an erased shard gives finite grads
    and the same loss as fault-free (recovery is exact)."""
    cfg = smoke_config(get_arch("granite-3-8b"))
    ctx = TPCtx(tp=4, mode="coded", code_r=2, moe_capacity=0)
    m = build(cfg, ctx)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.dummy_batch(jax.random.PRNGKey(1), 2, 8)
    from repro.train.train_step import lm_loss

    def loss(p, valid):
        return lm_loss(m.forward(p, batch, valid, remat="none"),
                       batch["tokens"], cfg.vocab)

    l_ok = float(loss(params, jnp.ones(4, bool)))
    l_fail = float(loss(params, jnp.ones(4, bool).at[2].set(False)))
    assert abs(l_ok - l_fail) < 1e-3
    g = jax.grad(loss)(params, jnp.ones(4, bool).at[2].set(False))
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))
