"""Request span trees and SLO attribution (``repro.obs.spans`` / ``.slo``).

Tier-1 properties: a seeded chaos run traced twice yields bit-identical
span trees (wall fields quarantined out of ``comparable``), every
completed request's tree is closed and gap-free with top-level phases
tiling [arrival, terminal], the TTFT/latency decompositions tile exactly
(including through 2MR requeues, whose first-token reset the span tree
mirrors), every deadline miss is attributed to exactly one cause, sheds
carry their queue-stamped reason into trees and Prometheus counters, the
Perfetto export passes ``require_span_closure`` and fails it when
tampered with, flow arrows link decode slices to executor rounds and
fault_recovery spans to injector erasures, and the ``repro.obs.slo``
CLI re-renders the same report from the trace file alone.
"""
import json

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import get_arch, smoke_config
from repro.faults import ChaosSpec, FaultInjector, attach_chaos
from repro.models import TPCtx, build
from repro.obs import (FlightRecorder, prometheus_text,
                       validate_chrome_trace, write_chrome_trace)
from repro.obs.slo import (CAUSES, attribute, decompose, decompositions,
                           main as slo_main, rows_from_trace, summarize)
from repro.obs.spans import (SPAN_DECODE, SPAN_FAULT_RECOVERY, SPAN_PREFILL,
                             SPAN_QUEUE_WAIT, GAP_EPS_MS, Span, SpanTracker)
from repro.runtime import (ContinuousBatchingScheduler, RuntimeConfig,
                           ShardHealthController, SimClock, run_arrivals)
from repro.runtime.metrics import RuntimeMetrics
from repro.runtime.queue import AdmissionQueue
from repro.runtime.request import Request

GEN = 6
PROMPT_LEN = 8
EPS = 1e-6


def _req(rid, arrival_ms=0.0, deadline_ms=None, priority=0):
    return Request(rid, np.arange(1, 5), max_new_tokens=8,
                   arrival_ms=arrival_ms, deadline_ms=deadline_ms,
                   priority=priority)


def _chaos_run(seed=0, n_requests=6):
    """Seeded churn run through the real scheduler (granite smoke)."""
    cfg = smoke_config(get_arch("granite-3-8b"))
    model = build(cfg, TPCtx(tp=4, mode="coded", code_r=2, moe_capacity=0))
    params = model.init(jax.random.PRNGKey(0))
    from repro.serve import ModelStepper
    stepper = ModelStepper(model, params, max_len=48)
    injector = FaultInjector(ChaosSpec(mtbf_ms=120.0, mttr_ms=30.0),
                             stepper.n_shards, seed=seed)
    health = ShardHealthController(stepper.n_shards, stepper.erasure_budget)
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=2, step_time_ms=10.0, seed=seed),
        health=health, tracer=FlightRecorder())
    attach_chaos(sched, injector)
    rng = np.random.default_rng(7)
    gap = 400.0 / n_requests
    workload = [(i * gap, rng.integers(0, cfg.vocab, PROMPT_LEN), GEN)
                for i in range(n_requests)]
    completed = run_arrivals(sched, workload)
    return sched, completed


@pytest.fixture(scope="module")
def chaos():
    return _chaos_run()


# ------------------------------------------------------------ tree unit ----

def test_span_name_and_close_contracts():
    with pytest.raises(ValueError, match="unknown span name"):
        Span("decode.rond", 0.0)
    s = Span(SPAN_DECODE, 10.0)
    with pytest.raises(ValueError, match="close before it opened"):
        s.close(5.0)
    s.close(20.0)
    assert s.dur_ms == 10.0
    with pytest.raises(RuntimeError, match="already closed"):
        s.close(30.0)


def test_tracker_lifecycle_builds_closed_tiled_tree():
    tr = SpanTracker()
    req = _req(0, arrival_ms=0.0)
    tr.on_submit(req)
    tr.on_admit(req, 10.0, prefill_wall_ms=1.5)
    tr.on_round(0, 10.0, 10.0, round_idx=0)
    tr.on_round(0, 20.0, 10.0, round_idx=1, stall_ms=4.0)
    req.reset_for_requeue()          # 2MR eviction discards both rounds
    tr.on_requeue(req, 30.0, fault={"fault_shard": 2, "fault_t_ms": 25.0,
                                    "fault_kind": "dead"})
    tr.on_heal(30.0, reencode_wall_ms=0.7)
    tr.on_admit(req, 50.0)
    tr.on_round(0, 50.0, 10.0, round_idx=5)
    tr.on_round(0, 60.0, 10.0, round_idx=6, stall_ms=3.0)
    req.tokens = [1, 2, 3]
    req.first_token_ms = 50.0        # re-issued by the post-requeue prefill
    tr.on_complete(req, 70.0)

    assert tr.check_all_closed() == 1
    tree = tr.terminal()[0]
    # the first-token reset mirrors into the tree: one prefill per
    # admission, stamped with the running requeue count
    assert [p.args["n_requeues"] for p in tree.by_name(SPAN_PREFILL)] == [0, 1]
    fr = tree.by_name(SPAN_FAULT_RECOVERY)
    assert len(fr) == 1 and fr[0].args["fault_shard"] == 2
    assert [c.name for c in fr[0].children] == ["requeue", "heal_wait"]

    row = decompose(tree)
    assert row["queue_wait_ms"] == 10.0
    assert row["decode_ms"] == 20.0          # kept episode only
    # kept-round stall only: the wasted episode's 4 ms stall is already
    # charged to fault_recovery wholesale
    assert row["stall_ms"] == 3.0
    assert row["fault_recovery_ms"] == 40.0  # 20 wasted decode + 20 requeue
    assert row["latency_ms"] == 70.0
    assert row["ttft_ms"] == 50.0
    assert abs(sum(row["ttft_decomp"].values()) - row["ttft_ms"]) < EPS
    assert row["tpot_ms"] == 10.0            # 20 kept ms / (3 - 1) tokens


def test_round_wall_attribution_buffers_both_directions():
    tr = SpanTracker()
    req = _req(0)
    tr.on_submit(req)
    tr.on_admit(req, 0.0)
    tr.on_round(0, 0.0, 10.0, round_idx=0)
    tr.on_round_wall(0, period_ms=3.0, block_ms=1.0)   # after the slice
    tr.on_round_wall(1, period_ms=5.0, block_ms=2.0)   # before the slice
    tr.on_round(0, 10.0, 10.0, round_idx=1)
    req.tokens = [1]
    tr.on_complete(req, 20.0)
    slices = tr.terminal()[0].by_name("decode.round")
    assert slices[0].wall_args == {"period_ms": 3.0, "block_ms": 1.0}
    assert slices[1].wall_args == {"period_ms": 5.0, "block_ms": 2.0}
    # and the quarantine holds: wall attribution never enters comparable()
    assert "period_ms" not in str(tr.comparable())


def test_capacity_ring_counts_drops():
    tr = SpanTracker(capacity=2)
    for rid in range(4):
        req = _req(rid, arrival_ms=float(rid))
        tr.on_submit(req)
        tr.on_shed(req, float(rid) + 1.0, "queue_full")
    assert len(tr.done) == 2 and tr.n_terminal == 4 and tr.dropped == 2
    assert [t.rid for t in tr.terminal()] == [2, 3]


# ------------------------------------------------------------- property ----

@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 4), data=st.data())
def test_random_lifecycles_stay_closed_and_tiled(n, data):
    """Random admit/evict/requeue/shed sequences driven directly on the
    tracker: every terminal tree passes the tiling contract and its
    decomposition tiles latency and TTFT exactly."""
    tr = SpanTracker()
    round_idx = 0
    for rid in range(n):
        t = data.draw(st.floats(0.0, 50.0))
        req = _req(rid, arrival_ms=t)
        tr.on_submit(req)
        if data.draw(st.integers(0, 4)) == 0:
            t += data.draw(st.floats(0.0, 30.0))
            tr.on_shed(req, t, data.draw(
                st.sampled_from(["queue_full", "displaced"])))
            continue
        episodes = 1 + data.draw(st.integers(0, 2))
        for ep in range(episodes):
            t += data.draw(st.floats(0.0, 30.0))        # queue / requeue wait
            tr.on_admit(req, t, prefill_wall_ms=0.1)
            if ep == episodes - 1:
                req.first_token_ms = t                  # surviving prefill
            for _ in range(data.draw(st.integers(1, 4))):
                dt = data.draw(st.floats(1.0, 20.0))
                stall = dt * data.draw(st.sampled_from([0.0, 0.25, 0.5]))
                tr.on_round(rid, t, dt, round_idx, stall_ms=stall)
                round_idx += 1
                t += dt
            if ep < episodes - 1:
                req.reset_for_requeue()
                tr.on_requeue(req, t, fault={"fault_shard": 0,
                                             "fault_t_ms": t,
                                             "fault_kind": "dead"})
                if data.draw(st.integers(0, 1)):
                    tr.on_heal(t)
        req.tokens = list(range(data.draw(st.integers(1, 6))))
        tr.on_complete(req, t)

    assert tr.check_all_closed() == n
    for row in decompositions(tr):
        parts = (row["queue_wait_ms"] + row["prefill_ms"] +
                 row["decode_ms"] + row["fault_recovery_ms"])
        assert abs(parts - row["latency_ms"]) < 1e-6 * max(1.0, parts)
        if row["state"] == "completed":
            assert abs(sum(row["ttft_decomp"].values()) -
                       row["ttft_ms"]) < 1e-6 * max(1.0, row["ttft_ms"])
        assert row["stall_ms"] <= row["decode_ms"] + EPS


# ----------------------------------------------------------- attribution ----

def test_attribution_exactly_one_cause():
    base = {"state": "completed", "queue_wait_ms": 0.0, "prefill_ms": 0.0,
            "stall_ms": 0.0, "fault_recovery_ms": 0.0}
    assert attribute({**base, "state": "shed"}) == "shed"
    assert attribute({**base, "stall_ms": 50.0,
                      "queue_wait_ms": 10.0}) == "straggler"
    assert attribute({**base, "fault_recovery_ms": 90.0,
                      "stall_ms": 10.0}) == "fault_recovery"
    # ties break in CAUSES order: earlier pipeline stage wins
    assert attribute({**base, "queue_wait_ms": 30.0,
                      "stall_ms": 30.0}) == "queue_wait"
    for row in ({**base, "state": "shed"},
                {**base, "stall_ms": 1.0},
                {**base, "queue_wait_ms": 1.0, "stall_ms": 1.0}):
        assert attribute(row) in CAUSES


def test_deadline_miss_attributed_from_tree():
    tr = SpanTracker()
    req = _req(0, deadline_ms=30.0)
    tr.on_submit(req)
    tr.on_admit(req, 45.0)                    # queue_wait blows the budget
    tr.on_round(0, 45.0, 10.0, round_idx=0)
    req.tokens = [1, 2]
    req.first_token_ms = 45.0
    tr.on_complete(req, 55.0)
    row = decompose(tr.terminal()[0])
    assert row["missed"] and row["cause"] == "queue_wait"
    s = summarize(tr)
    assert s["n_missed"] == 1
    assert s["miss_by_cause"]["queue_wait"] == 1
    assert sum(s["miss_by_cause"].values()) == 1   # exactly one cause


# ------------------------------------------------------------------ shed ----

def test_queue_stamps_shed_reason_into_tree():
    clock = SimClock()
    tr = SpanTracker()
    q = AdmissionQueue(max_depth=1, spans=tr, clock=clock)
    late = _req(0, arrival_ms=0.0, priority=0)
    tr.on_submit(late)
    assert q.push(late) is None
    clock.advance(5.0)
    urgent = _req(1, arrival_ms=5.0, priority=3)
    tr.on_submit(urgent)
    victim = q.push(urgent)                   # better-ordered arrival wins
    assert victim is late and late.shed_reason == "displaced"
    tree = tr.terminal()[0]
    assert tree.state == "shed"
    assert tree.root.args["shed_reason"] == "displaced"
    row = decompose(tree)
    assert row["missed"] and row["cause"] == "shed"
    assert row["latency_ms"] == 5.0           # queue_wait tiles the life

    overflow = _req(2, arrival_ms=6.0)        # full queue, sorted last
    tr.on_submit(overflow)
    assert q.push(overflow) is overflow
    assert overflow.shed_reason == "queue_full"
    assert summarize(tr)["shed_by_reason"] == {"queue_full": 1,
                                               "displaced": 1}


def test_prometheus_exports_shed_and_slo_series():
    clock = SimClock()
    tr = SpanTracker()
    q = AdmissionQueue(max_depth=1, spans=tr, clock=clock)
    metrics = RuntimeMetrics()
    for rid in range(3):
        req = _req(rid, arrival_ms=float(rid))
        tr.on_submit(req)
        victim = q.push(req)
        if victim is not None:
            metrics.count_shed(victim.shed_reason)
    text = prometheus_text(metrics, now_ms=clock.now(), spans=tr)
    assert 'repro_requests_shed_total{cause="queue_full"} 2' in text
    assert "repro_requests_requeued_total 0" in text
    assert 'repro_slo_shed_total{reason="queue_full"} 2' in text
    assert 'repro_slo_ttft_ms{quantile="0.99"}' in text


# ---------------------------------------------------------- integration ----

def test_chaos_replay_span_trees_bit_identical(chaos):
    sched_a, _ = chaos
    sched_b, _ = _chaos_run()
    assert len(sched_a.spans.done) > 0
    assert sched_a.spans.comparable() == sched_b.spans.comparable()
    # ... while the quarantined wall stamps are free to differ
    wall = lambda s: [t.root.wall_t0_ms for t in s.spans.terminal()]
    assert wall(sched_a) != wall(sched_b)


def test_chaos_run_all_completed_trees_closed(chaos):
    sched, completed = chaos
    n = sched.metrics.counters["requests_completed"]
    assert n == len(completed) > 0
    assert sched.spans.check_all_closed() == n       # 100% closed + tiled
    assert len(sched.spans.open) == 0
    # the chaos schedule must actually exercise the 2MR path for the
    # requeue assertions below to mean anything
    assert sched.metrics.counters["requests_requeued"] > 0
    rows = decompositions(sched.spans)
    requeued = [r for r in rows if r["n_requeues"] > 0]
    assert requeued
    for row in requeued:
        assert row["fault_recovery_ms"] > 0
        assert abs(sum(row["ttft_decomp"].values()) - row["ttft_ms"]) < 1e-6
    for row in rows:
        assert (row["cause"] in CAUSES) == row["missed"]
    text = prometheus_text(sched.metrics, sched.shardlog,
                           now_ms=sched.clock.now(), recorder=sched.tracer,
                           spans=sched.spans)
    assert (f"repro_requests_requeued_total "
            f"{sched.metrics.counters['requests_requeued']}") in text


def test_trace_export_validates_and_rejects_tampering(chaos, tmp_path):
    sched, _ = chaos
    path = tmp_path / "chaos.trace.json"
    write_chrome_trace(str(path), sched.tracer, sched.shardlog,
                       now_ms=sched.clock.now(), spans=sched.spans)
    trace = json.loads(path.read_text())
    stats = validate_chrome_trace(trace, require_fault_links=True,
                                  require_span_closure=True)
    assert stats["n_span_trees"] == len(sched.spans.done)
    assert stats["n_span_slices"] > 0
    assert stats["n_fault_recovery_spans"] > 0
    assert stats["n_unlinked_fault_recovery"] == 0
    assert stats["n_flow_ids"] > 0

    # drop one span-end event: closure validation must fail
    tampered = dict(trace)
    events = list(trace["traceEvents"])
    idx = next(i for i, e in enumerate(events)
               if e.get("cat") == "span" and e.get("ph") == "e")
    tampered["traceEvents"] = events[:idx] + events[idx + 1:]
    with pytest.raises(ValueError, match="span"):
        validate_chrome_trace(tampered, require_span_closure=True)

    # a spanless trace cannot satisfy the closure requirement
    spanless = dict(trace)
    spanless["traceEvents"] = [e for e in events if e.get("cat") != "span"]
    with pytest.raises(ValueError, match="no request span trees"):
        validate_chrome_trace(spanless, require_span_closure=True)


def test_slo_cli_reproduces_report_from_trace(chaos, tmp_path, capsys):
    sched, _ = chaos
    path = tmp_path / "chaos.trace.json"
    write_chrome_trace(str(path), sched.tracer, sched.shardlog,
                       now_ms=sched.clock.now(), spans=sched.spans)
    rows = rows_from_trace(json.loads(path.read_text()))
    assert [r["rid"] for r in rows] == \
        [t.rid for t in sched.spans.terminal()]

    assert slo_main(["report", "--trace", str(path)]) == 0
    out = capsys.readouterr().out
    assert "latency percentiles (sim ms)" in out and "tpot_ms" in out

    assert slo_main(["report", "--trace", str(path), "--json"]) == 0
    summary = json.loads(capsys.readouterr().out)
    live = summarize(sched.spans)
    assert summary["n_requests"] == live["n_requests"]
    assert summary["ttft_p99_ms"] == pytest.approx(live["ttft_p99_ms"])

    empty = tmp_path / "empty.trace.json"
    empty.write_text(json.dumps({"traceEvents": []}))
    assert slo_main(["report", "--trace", str(empty)]) == 2
