"""Batched slot executor: vmapped rounds, per-slot KV positions, fused
coded decode, SLO admission.

The tier-1 properties of the one-dispatch-per-round engine:
  (a) the stacked round is token-for-token identical to sequential
      per-slot stepping across staggered admission (slots at different KV
      positions), with and without host/device overlap;
  (b) every in-budget erasure index under the batched round still yields
      exact logits (the paper's close-to-zero recovery, pool-wide);
  (c) the Pallas fused coded-head decode matches the reference decode on
      the (T, r) grid;
plus: a scheduler round with n_slots >= 4 issues ONE jitted dispatch (no
per-slot stepping on the hot path), and the deadline/shedding admission
queue orders and bounds correctly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.kernels import ops, ref
from repro.models import TPCtx, build
from repro.runtime import (AdmissionQueue, ContinuousBatchingScheduler,
                           Request, RequestState, RuntimeConfig,
                           ShardHealthController, erasure, run_arrivals)
from repro.runtime.executor import (SlotPoolExecutor, VStep, read_slot,
                                    stack_states, supports_slot_batching,
                                    unstack_states, write_slot)
from repro.serve import ModelStepper, ServeConfig, ServingEngine

GEN = 5
T, R = 4, 2


@pytest.fixture(scope="module")
def coded():
    cfg = smoke_config(get_arch("granite-3-8b"))
    model = build(cfg, TPCtx(tp=T, mode="coded", code_r=R, moe_capacity=0))
    params = model.init(jax.random.PRNGKey(0))
    stepper = ModelStepper(model, params, max_len=48)
    return cfg, stepper


def _staggered(cfg, n, base_len=4):
    """Prompts of different lengths arriving at different times — slots
    end up at genuinely different KV positions."""
    rng = np.random.default_rng(3)
    return [(i * 1.5, rng.integers(0, cfg.vocab, base_len + i % 4), GEN)
            for i in range(n)]


def _serve(stepper, arrivals, *, batched, n_slots=4, overlap=True,
           events=(), use_fused="auto"):
    health = ShardHealthController(stepper.n_shards, stepper.erasure_budget,
                                   events=list(events))
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=n_slots, batched=batched,
                               overlap=overlap, use_fused=use_fused),
        health=health)
    done = run_arrivals(sched, [(t, p, n) for t, p, n in arrivals])
    return sched, {r.rid: r.tokens for r in done}


# ------------------------------------------------- (a) round equivalence ----

def test_batched_round_matches_sequential_staggered(coded):
    """Stacked one-dispatch rounds == sequential per-slot stepping,
    token for token, with slots admitted at different KV positions —
    in both overlap modes."""
    cfg, stepper = coded
    arrivals = _staggered(cfg, 6)
    s_seq, toks_seq = _serve(stepper, arrivals, batched=False)
    s_b, toks_b = _serve(stepper, arrivals, batched=True, overlap=True)
    s_bn, toks_bn = _serve(stepper, arrivals, batched=True, overlap=False)
    assert len(toks_seq) == 6
    assert toks_b == toks_seq
    assert toks_bn == toks_seq
    assert all(len(t) == GEN for t in toks_b.values())
    # both executions measured real round latency
    assert len(s_b.metrics.round_ms) > 0
    assert len(s_seq.metrics.round_ms) > 0


def test_one_round_is_one_dispatch(coded):
    """n_slots >= 4: a decode round is ONE jitted dispatch for the whole
    pool — one trace ever, dispatches == rounds, and the per-slot
    ``decode_one`` stepper is never touched on the hot path."""
    cfg, stepper = coded
    calls = {"decode_one": 0}
    orig = stepper.decode_one
    stepper.decode_one = lambda *a, **k: calls.__setitem__(
        "decode_one", calls["decode_one"] + 1) or orig(*a, **k)
    try:
        sched, toks = _serve(stepper, _staggered(cfg, 8), batched=True,
                             n_slots=4)
    finally:
        stepper.decode_one = orig
    assert calls["decode_one"] == 0, "per-slot Python-loop stepping on " \
                                     "the batched hot path"
    vstep = sched.executor.vstep
    assert vstep.n_traces == 1, "round retraced: admission/mask changed " \
                                "compiled shapes"
    assert vstep.n_dispatches == sched.metrics.counters["decode_rounds"]
    assert sched.metrics.counters["requests_completed"] == 8


def test_slot_write_read_roundtrip(coded):
    cfg, stepper = coded
    rng = np.random.default_rng(0)
    ex = SlotPoolExecutor(stepper, n_slots=3, overlap=False)
    mask = np.ones(T, bool)
    prompt = rng.integers(0, cfg.vocab, 6)
    ex.admit(1, prompt, mask, tag="x")
    row = read_slot(ex.state, 1)
    # the written row really sits at slot 1 with its own position vector
    assert int(row["kv"]["len"][0, 0]) == len(prompt)
    assert int(read_slot(ex.state, 0)["kv"]["len"][0, 0]) == 0
    back = write_slot(ex.state, 2, row)
    assert int(jax.tree.leaves({"l": back["kv"]["len"]})[0][0][2]) \
        == len(prompt)
    # unstack -> stack is the identity on the slot axis
    restacked = stack_states(unstack_states(ex.state, 3))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 ex.state, restacked)


# --------------------------------------------- (b) erasure exact logits ----

def test_every_inbudget_erasure_exact_logits(coded):
    """Each erasable shard index under the batched round: logits of the
    whole stacked pool match the fault-free round exactly (recovery
    in-step, for every slot at once)."""
    cfg, stepper = coded
    rng = np.random.default_rng(1)
    ex = SlotPoolExecutor(stepper, n_slots=4, overlap=False)
    full = np.ones(T, bool)
    for i, plen in enumerate((4, 6, 7, 5)):     # staggered KV positions
        ex.admit(i, rng.integers(0, cfg.vocab, plen), full, tag=i)
    vstep = ex.vstep
    _, toks_ok, logits_ok = vstep.round(ex.state, ex.last_toks, full)
    assert logits_ok is not None
    for shard in range(T):
        mask = full.copy()
        mask[shard] = False
        _, toks_f, logits_f = vstep.round(ex.state, ex.last_toks, mask)
        np.testing.assert_allclose(np.asarray(logits_f),
                                   np.asarray(logits_ok),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"shard {shard}")
        np.testing.assert_array_equal(np.asarray(toks_f),
                                      np.asarray(toks_ok))


def test_scheduler_erasure_stream_identical(coded):
    """Mid-stream erasure through the batched scheduler: same tokens as
    the fault-free run, recovered in-step, nothing requeued."""
    cfg, stepper = coded
    arrivals = _staggered(cfg, 4)
    _, toks_ok = _serve(stepper, arrivals, batched=True)
    s_f, toks_f = _serve(stepper, arrivals, batched=True,
                         events=[erasure(2.0, 1)])
    assert toks_f == toks_ok
    assert s_f.metrics.counters["erasures_recovered"] == 1
    assert s_f.metrics.counters["requests_requeued"] == 0


# ------------------------------------------------ (c) fused Pallas head ----

@pytest.mark.parametrize("t", [2, 4])
@pytest.mark.parametrize("r", [1, 2])
def test_fused_head_matches_reference_grid(t, r):
    """Pallas fused coded-matmul + parity-decode + argmax == reference
    decode, fault-free and under every single erasure (any r >= 1 carries
    the all-ones sum parity the fused kernel consumes)."""
    rng = np.random.default_rng(t * 10 + r)
    b, k, m = 3, 32, 8 * t * t
    x = jnp.asarray(rng.normal(size=(b, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(t, k, m // t)), jnp.float32)
    pw = w.sum(0)
    merged = jnp.moveaxis(jnp.einsum("bk,tkn->tbn", x, w), 0, -2)
    truth = jnp.argmax(merged.reshape(b, -1), -1)
    for dead in [None] + list(range(t)):
        valid = jnp.ones(t, bool)
        if dead is not None:
            valid = valid.at[dead].set(False)
        tok, val = ops.fused_head_argmax(x, w, pw, valid, vocab=m)
        rtok, rval = ref.fused_head_argmax_ref(x, w, pw, valid, m)
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(rtok))
        np.testing.assert_array_equal(np.asarray(tok), np.asarray(truth))
        np.testing.assert_allclose(np.asarray(val), np.asarray(rval),
                                   rtol=1e-5)


def test_fused_round_matches_reference_round(coded):
    """End-to-end: the fused-head batched round produces the same next
    tokens as the reference (full-logits) round, fault-free and with one
    erased shard."""
    cfg, stepper = coded
    rng = np.random.default_rng(5)
    ex = SlotPoolExecutor(stepper, n_slots=4, overlap=False)
    full = np.ones(T, bool)
    for i, plen in enumerate((4, 6, 7, 5)):
        ex.admit(i, rng.integers(0, cfg.vocab, plen), full, tag=i)
    ref_step = VStep(stepper, use_fused=False)
    fused_step = VStep(stepper, use_fused=True)
    assert fused_step.use_fused, "fused path must be available for the " \
                                 "coded transformer"
    for mask in (full, np.array([True, False, True, True])):
        _, toks_ref, _ = ref_step.round(ex.state, ex.last_toks, mask)
        _, toks_fused, logits = fused_step.round(ex.state, ex.last_toks,
                                                 mask)
        assert logits is None, "fused round must not materialise logits"
        np.testing.assert_array_equal(np.asarray(toks_fused),
                                      np.asarray(toks_ref))


def test_fused_falls_back_beyond_eq12(coded):
    """Two dead shards exceed the sum-parity regime: the fused executor
    silently uses the reference MDS path (and still returns logits)."""
    cfg, stepper = coded
    rng = np.random.default_rng(6)
    ex = SlotPoolExecutor(stepper, n_slots=2, overlap=False)
    full = np.ones(T, bool)
    ex.admit(0, rng.integers(0, cfg.vocab, 4), full, tag=0)
    fused_step = VStep(stepper, use_fused=True)
    mask2 = np.array([True, False, False, True])
    _, _, logits = fused_step.round(ex.state, ex.last_toks, mask2)
    assert logits is not None


# ------------------------------------------------- legacy facade parity ----

def test_serving_engine_delegates_to_executor(coded):
    """The deprecated ServingEngine facade and the raw sequential stepper
    loop agree token-for-token — the facade can't silently diverge from
    the batched path it now delegates to."""
    cfg, stepper = coded
    model = stepper.model
    eng = ServingEngine(model, stepper._raw_params,
                        ServeConfig(max_len=48, batch=2,
                                    cache_dtype=jnp.float32))
    batch = model.dummy_batch(jax.random.PRNGKey(1), 2, 8)
    got = eng.generate(batch, 6, fail_at={2: 1})
    eng2 = ServingEngine(model, stepper._raw_params,
                         ServeConfig(max_len=48, batch=2,
                                     cache_dtype=jnp.float32))
    eng2.inject_failure(1)  # pre-kill so the sequential run sees the same
    eng2.metrics["erasures_recovered"] = 0
    want_pre = eng2._generate_sequential(batch, 6, fail_at=None)
    # tokens after the injection step must match the always-degraded run;
    # before it, the healthy run (coded recovery is exact either way)
    healthy = ServingEngine(model, stepper._raw_params,
                            ServeConfig(max_len=48, batch=2,
                                        cache_dtype=jnp.float32))
    want_ok = healthy._generate_sequential(batch, 6, fail_at=None)
    np.testing.assert_array_equal(got, want_ok)
    np.testing.assert_array_equal(got, want_pre)


# ------------------------------------------------------- SLO admission ----

def _req(rid, arrival=0.0, deadline=None, priority=0):
    return Request(rid, np.array([1], np.int32), 1, arrival_ms=arrival,
                   deadline_ms=deadline, priority=priority)


def test_admission_queue_deadline_order():
    q = AdmissionQueue()
    q.push(_req(0, arrival=0.0))                      # best effort
    q.push(_req(1, arrival=1.0, deadline=50.0))
    q.push(_req(2, arrival=2.0, deadline=10.0))
    q.push(_req(3, arrival=3.0, priority=1))          # priority trumps all
    assert [q.pop().rid for _ in range(4)] == [3, 2, 1, 0]


def test_admission_queue_fifo_when_unconfigured():
    q = AdmissionQueue()
    for i, t in enumerate((0.0, 1.0, 2.0)):
        q.push(_req(i, arrival=t))
    # a 2MR requeue keeps its original arrival and re-enters ahead
    q.push(_req(9, arrival=0.5), force=True)
    assert [q.pop().rid for _ in range(4)] == [0, 9, 1, 2]


def test_admission_queue_sheds_worst():
    q = AdmissionQueue(max_depth=2)
    assert q.push(_req(0, deadline=10.0)) is None
    assert q.push(_req(1, deadline=20.0)) is None
    shed = q.push(_req(2, deadline=5.0))   # tightest deadline stays
    assert shed is not None and shed.rid == 1
    assert q.push(_req(3, deadline=99.0)).rid == 3   # incoming is worst
    assert len(q) == 2
    with pytest.raises(ValueError):
        AdmissionQueue(max_depth=0)


def test_admission_queue_never_sheds_requeued_work():
    """A 2MR-requeued (once-admitted) request is protected from shedding
    even as the victim of a LATER push — 'never loses a request' holds
    for admitted work under any queue pressure."""
    q = AdmissionQueue(max_depth=1)
    requeued = _req(0)                  # worst-ordered: no deadline
    requeued.n_requeues = 1
    q.push(requeued, force=True)
    fresh = _req(1, deadline=5.0)       # sorts BEFORE the requeued one
    shed = q.push(fresh)
    assert shed is not None and shed.rid == 1, \
        "the sheddable newcomer must be dropped, not the admitted request"
    assert [r.rid for r in q] == [0]
    # all-protected queue: the bound yields rather than shedding
    q2 = AdmissionQueue(max_depth=1)
    for rid in (0, 1):
        r = _req(rid)
        r.n_requeues = 1
        assert q2.push(r, force=True) is None
    assert q2.push(_req(2, deadline=1.0)).rid == 2
    assert len(q2) == 2


def test_scheduler_sheds_and_reports(coded):
    """Queue-depth bound under a burst: shed count and queue depth land in
    RuntimeMetrics; everything admitted still completes."""
    cfg, stepper = coded
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=1, max_queue_depth=2))
    rng = np.random.default_rng(2)
    reqs = [sched.submit(rng.integers(0, cfg.vocab, 4), 2,
                         deadline_ms=100.0 + i) for i in range(6)]
    done = sched.run()
    c = sched.metrics.counters
    # all 6 land before the first round: the bound keeps 2, sheds 4
    assert c["requests_shed"] == 4 == len(sched.shed)
    assert all(r.state is RequestState.SHED for r in sched.shed)
    assert c["requests_completed"] == len(done) == 2
    assert c["requests_submitted"] == 6
    snap = sched.metrics.snapshot()
    assert snap["queue_depth"]["max"] <= 2
    # the survivors are the earliest deadlines (first-come here)
    assert {r.rid for r in done} == {0, 1}


def test_deadline_reorders_admission(coded):
    """A later-arriving tighter-deadline request is admitted before an
    earlier best-effort one."""
    cfg, stepper = coded
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=1))
    rng = np.random.default_rng(4)
    p = lambda: rng.integers(0, cfg.vocab, 4)
    r_early = sched.submit(p(), 3)                    # FIFO, submitted 1st
    r_slow = sched.submit(p(), 2)
    r_urgent = sched.submit(p(), 2, deadline_ms=5.0)  # submitted LAST
    sched.run()
    # deadline-ordered pop: urgent wins the single slot outright
    assert r_urgent.admitted_ms < r_early.admitted_ms < r_slow.admitted_ms


# ----------------------------------------------------- support surface ----

def test_supports_slot_batching_universal():
    """Every zoo family slot-batches now (enc-dec via the extras bank,
    xLSTM via its positionless axis-0 block state); the detailed
    per-architecture equivalence lives in test_executor_conformance.py."""
    for arch in ("xlstm-125m", "whisper-medium", "granite-3-8b"):
        assert supports_slot_batching(build(smoke_config(get_arch(arch)),
                                            TPCtx()))


def test_sequential_oracle_survives_for_xlstm():
    """``batched=False`` keeps the sequential per-slot path alive as the
    differential-test oracle / --sequential escape hatch; the default is
    the batched executor even for xLSTM."""
    cfg = smoke_config(get_arch("xlstm-125m"))
    model = build(cfg, TPCtx())
    params = model.init(jax.random.PRNGKey(0))
    stepper = ModelStepper(model, params, max_len=32)
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=2, batched=False))
    assert sched.executor is None
    rng = np.random.default_rng(0)
    done = run_arrivals(sched, [(0.0, rng.integers(0, cfg.vocab, 4), 3),
                                (1.0, rng.integers(0, cfg.vocab, 4), 3)])
    assert len(done) == 2 and all(len(r.tokens) == 3 for r in done)
    auto = ContinuousBatchingScheduler(stepper, RuntimeConfig(n_slots=2))
    assert auto.executor is not None
