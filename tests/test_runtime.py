"""Coded cluster runtime: scheduler + health controller under a
deterministic simulated clock.

The tier-1 properties: FIFO admission, slot reuse under continuous
batching, no request lost across a mid-decode erasure (CDC path), requeue
+ heal on beyond-budget failures (2MR path), and metrics counters that
add up.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.core.policy import INPUT_SPLIT
from repro.models import TPCtx, build
from repro.runtime import (ContinuousBatchingScheduler, EventKind,
                           HealthAction, RuntimeConfig, SimClock,
                           ShardHealthController, erasure, recovery,
                           replica_failure, run_arrivals)
from repro.serve import ModelStepper

GEN = 6
PROMPT_LEN = 8


@pytest.fixture(scope="module")
def coded():
    cfg = smoke_config(get_arch("granite-3-8b"))
    model = build(cfg, TPCtx(tp=4, mode="coded", code_r=2, moe_capacity=0))
    params = model.init(jax.random.PRNGKey(0))
    stepper = ModelStepper(model, params, max_len=48)
    return cfg, stepper


def _prompts(cfg, n):
    rng = np.random.default_rng(7)
    return [rng.integers(0, cfg.vocab, PROMPT_LEN) for _ in range(n)]


def _sched(stepper, n_slots=2, events=None, **kw):
    health = ShardHealthController(stepper.n_shards,
                                   stepper.erasure_budget,
                                   events=list(events or []))
    return ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=n_slots, **kw), health=health)


# ------------------------------------------------- scheduler semantics ----

def test_fifo_admission_and_slot_reuse(coded):
    cfg, stepper = coded
    sched = _sched(stepper, n_slots=2)
    reqs = [sched.submit(p, GEN) for p in _prompts(cfg, 5)]
    done = sched.run()

    assert len(done) == 5 and not sched.busy
    # FIFO: requests enter slots in submission order
    admits = sorted(reqs, key=lambda r: (r.admitted_ms, r.rid))
    assert [r.rid for r in admits] == [0, 1, 2, 3, 4]
    # continuous batching: only 2 slots existed, so slots were reused
    assert sum(s.occupancies for s in sched.slots) == 5
    assert max(s.occupancies for s in sched.slots) >= 2
    # later requests waited in queue under a deterministic clock
    assert reqs[0].queueing_ms == 0.0
    assert reqs[4].queueing_ms > 0.0
    assert all(len(r.tokens) == GEN for r in done)


def test_no_request_lost_across_mid_decode_erasure(coded):
    """Case Study II under load: shard dies while slots are decoding;
    tokens identical to the fault-free stream, nothing requeued."""
    cfg, stepper = coded
    prompts = _prompts(cfg, 4)

    def serve(events):
        sched = _sched(stepper, n_slots=2, events=events)
        done = run_arrivals(sched, [(0.0, p, GEN) for p in prompts])
        return sched, {r.rid: r.tokens for r in done}

    s_ok, toks_ok = serve([])
    s_f, toks_f = serve([erasure(2.0, 1)])   # mid-decode of first 2 slots
    assert len(toks_f) == 4
    assert toks_f == toks_ok
    assert s_f.metrics.counters["erasures_recovered"] == 1
    assert s_f.metrics.counters["requests_requeued"] == 0
    assert s_f.metrics.counters["beyond_budget_failures"] == 0


def test_requeue_on_beyond_budget_failure(coded):
    """Two erasures against a budget of one: the 2MR half of the hybrid —
    in-flight requests requeue, the replica heals, parity re-encodes, and
    the stream still drains completely."""
    cfg, stepper = coded
    assert stepper.erasure_budget == 1
    sched = _sched(stepper, n_slots=2,
                   events=[erasure(2.0, 1), erasure(3.0, 2)])
    done = run_arrivals(sched, [(0.0, p, GEN) for p in _prompts(cfg, 4)])

    c = sched.metrics.counters
    assert len(done) == 4, "a request was lost"
    assert c["requests_completed"] == c["requests_submitted"] == 4
    assert c["erasures_recovered"] == 1       # first erasure: CDC path
    assert c["beyond_budget_failures"] == 1   # second: 2MR path
    assert c["requests_requeued"] >= 1
    assert c["parity_reencodes"] >= 1
    assert sched.health.mask.all(), "replica swap must heal all shards"
    assert all(len(r.tokens) == GEN for r in done)
    assert max(r.n_requeues for r in done) == 1


def test_recovery_event_heals_and_reencodes(coded):
    cfg, stepper = coded
    sched = _sched(stepper, n_slots=2,
                   events=[erasure(2.0, 1), recovery(4.0, 1)])
    done = run_arrivals(sched, [(0.0, p, GEN) for p in _prompts(cfg, 2)])
    c = sched.metrics.counters
    assert len(done) == 2
    assert c["erasures_recovered"] == 1
    assert c["shards_healed"] == 1
    assert c["parity_reencodes"] == 1
    assert sched.health.mask.all()


def test_deterministic_clock_repeatability(coded):
    """Same workload + SimClock twice => bit-identical tokens and
    simulated metrics. The MEASURED wall-clock round series is real
    hardware time and only repeats in count, not values."""
    cfg, stepper = coded
    prompts = _prompts(cfg, 3)

    def once():
        sched = _sched(stepper, n_slots=2, events=[erasure(1.0, 0)])
        done = run_arrivals(sched, [(i * 3.0, p, GEN)
                                    for i, p in enumerate(prompts)])
        return {r.rid: r.tokens for r in done}, sched.metrics.snapshot()

    toks_a, snap_a = once()
    toks_b, snap_b = once()
    assert toks_a == toks_b
    meas_a = snap_a.pop("round_latency_measured")
    meas_b = snap_b.pop("round_latency_measured")
    assert meas_a["n"] == meas_b["n"] > 0
    assert snap_a == snap_b


def test_deterministic_chaos_repeatability(coded):
    """Seed plumbing: ONE root seed threads the straggler stream, the
    fault injector, and the injected latency process, so a chaos run —
    fault schedule included — replays bit-exact. As with the plain
    determinism test, the MEASURED wall-clock series only repeats in
    count, not values."""
    from repro.faults import (ChaosSpec, FaultInjector, InjectedLatency,
                              LatencySpec, attach_chaos)
    from repro.core.failure import StragglerModel
    cfg, stepper = coded
    prompts = _prompts(cfg, 3)
    spec = ChaosSpec(mtbf_ms=60.0, mttr_ms=12.0, p_degraded=0.25)
    root_seed = 11

    def once():
        injector = FaultInjector(spec, stepper.n_shards, seed=root_seed)
        latency = InjectedLatency(
            LatencySpec(base=StragglerModel(floor_ms=1.0, mu=0.0,
                                            sigma=0.5)),
            injector, seed=root_seed)
        sched = _sched(stepper, n_slots=2, seed=root_seed)
        sched.latency = latency
        attach_chaos(sched, injector)
        done = run_arrivals(sched, [(i * 3.0, p, GEN)
                                    for i, p in enumerate(prompts)])
        return {r.rid: r.tokens for r in done}, sched.metrics.snapshot()

    toks_a, snap_a = once()
    toks_b, snap_b = once()
    assert toks_a == toks_b
    assert snap_a["counters"]["faults_injected"] > 0
    meas_a = snap_a.pop("round_latency_measured")
    meas_b = snap_b.pop("round_latency_measured")
    assert meas_a["n"] == meas_b["n"] > 0
    assert snap_a == snap_b


def test_metrics_counters_add_up(coded):
    cfg, stepper = coded
    sched = _sched(stepper, n_slots=2)
    n = 4
    done = run_arrivals(sched, [(0.0, p, GEN) for p in _prompts(cfg, n)])
    c = sched.metrics.counters
    snap = sched.metrics.snapshot()
    assert c["tokens_generated"] == n * GEN == sum(
        len(r.tokens) for r in done)
    assert c["requests_admitted"] == c["requests_completed"] == n
    assert snap["request_latency"]["n"] == n
    assert snap["throughput"]["tokens_per_s"] > 0
    assert snap["queue_depth"]["max"] >= 2          # only 2 slots for 4 reqs
    # deterministic clock: elapsed is exactly the decode rounds
    assert snap["elapsed_ms"] == pytest.approx(
        c["decode_rounds"] * sched.rcfg.step_time_ms)


def test_idle_gap_fast_forwards_clock(coded):
    cfg, stepper = coded
    sched = _sched(stepper, n_slots=2)
    prompts = _prompts(cfg, 2)
    run_arrivals(sched, [(0.0, prompts[0], 2), (500.0, prompts[1], 2)])
    assert sched.clock.now() >= 500.0
    assert sched.metrics.counters["requests_completed"] == 2


# --------------------------------------------- enc-dec batched executor ----

def test_encdec_batched_heals_and_reencodes_on_midrun_failure():
    """PR 4 pinned this on the sequential fallback; enc-dec now rides the
    BATCHED executor: a mid-run in-budget erasure must recover in-step
    and a beyond-budget failure must still requeue + heal + re-encode
    (the 2MR re-admission re-runs the encoder, re-encoding the slot's
    extras-bank row), tokens identical to the fault-free stream — and the
    whole run replays bit-exact under the core.seeds root seed."""
    cfg = smoke_config(get_arch("whisper-medium"))
    model = build(cfg, TPCtx(tp=4, mode="coded", code_r=2, moe_capacity=0))
    params = model.init(jax.random.PRNGKey(0))
    stepper = ModelStepper(model, params, max_len=32)
    assert stepper.erasure_budget == 1
    rng = np.random.default_rng(11)
    frames = rng.normal(size=(cfg.enc_seq, cfg.d_model)).astype(np.float32)
    prompts = _prompts(cfg, 3)

    def serve(events, seed=0):
        sched = _sched(stepper, n_slots=2, events=events, seed=seed)
        assert sched.executor is not None, \
            "enc-dec must run the batched executor by default"
        for i, p in enumerate(prompts):
            sched.submit(p, GEN, extras={"frames": frames})
        done = sched.run()
        return sched, {r.rid: r.tokens for r in done}

    s_ok, toks_ok = serve([])
    assert len(toks_ok) == 3

    # in-budget: shard dies mid-decode, CDC recovers in-step pool-wide
    s_cdc, toks_cdc = serve([erasure(2.0, 1)])
    assert toks_cdc == toks_ok
    assert s_cdc.metrics.counters["erasures_recovered"] == 1
    assert s_cdc.metrics.counters["beyond_budget_failures"] == 0

    # beyond budget: 2nd concurrent erasure takes the 2MR fallback —
    # requeue in-flight, swap the replica in, re-encode parity (and the
    # extras bank, via re-admission)
    s_2mr, toks_2mr = serve([erasure(2.0, 1), erasure(3.0, 2)])
    c = s_2mr.metrics.counters
    assert toks_2mr == toks_ok, "a request was lost or corrupted"
    assert c["beyond_budget_failures"] == 1
    assert c["requests_requeued"] >= 1
    assert c["shards_healed"] >= 2
    assert c["parity_reencodes"] >= 1
    assert s_2mr.health.mask.all(), "replica swap must heal all shards"

    # bit-exact replay from one root seed (measured wall-clock excluded)
    s_a, toks_a = serve([erasure(2.0, 1), erasure(3.0, 2)], seed=7)
    s_b, toks_b = serve([erasure(2.0, 1), erasure(3.0, 2)], seed=7)
    assert toks_a == toks_b == toks_ok
    snap_a, snap_b = s_a.metrics.snapshot(), s_b.metrics.snapshot()
    snap_a.pop("round_latency_measured")
    snap_b.pop("round_latency_measured")
    assert snap_a == snap_b

    # the sequential oracle agrees across the same schedules
    seq = _sched(stepper, n_slots=2, batched=False,
                 events=[erasure(2.0, 1), erasure(3.0, 2)])
    for p in prompts:
        seq.submit(p, GEN, extras={"frames": frames})
    toks_seq = {r.rid: r.tokens for r in seq.run()}
    assert toks_seq == toks_ok


# --------------------------------------------- health controller (pure) ----

def test_health_budget_and_actions():
    h = ShardHealthController(4, budget=1)
    assert h.apply(erasure(0.0, 1)) is HealthAction.CONTINUE
    assert h.n_dead == 1
    assert h.apply(erasure(1.0, 2)) is HealthAction.REQUEUE
    assert h.replace_replica() == 2
    assert h.mask.all()
    assert h.apply(replica_failure(2.0)) is HealthAction.REQUEUE
    assert h.apply(erasure(3.0, 0)) is HealthAction.CONTINUE
    assert h.apply(recovery(4.0, 0)) is HealthAction.REENCODE
    assert h.mask.all()


def test_health_poll_applies_events_in_time_order():
    h = ShardHealthController(4, budget=2,
                              events=[erasure(5.0, 1), erasure(1.0, 0)])
    assert h.poll(0.5) == []
    acts = h.poll(10.0)
    assert acts == [HealthAction.CONTINUE, HealthAction.CONTINUE]
    assert [ev.shard for ev, _ in h.log] == [0, 1]   # time order, not insert
    assert h.n_dead == 2


def test_table1_gate_zeroes_budget_for_unsuitable_split():
    """core.policy tie-in: an input-split layer cannot carry offline
    parity, so its runtime erasure budget is zero regardless of r."""
    h = ShardHealthController(4, budget=2, split=INPUT_SPLIT)
    assert h.budget == 0
    assert h.apply(erasure(0.0, 1)) is HealthAction.REQUEUE


def test_duplicate_events_are_noops():
    """One physical failure reported twice must count once (telemetry and
    budget); recovering an alive shard is likewise a no-op."""
    h = ShardHealthController(4, budget=1)
    assert h.apply(erasure(0.0, 1)) is HealthAction.CONTINUE
    assert h.apply(erasure(1.0, 1)) is HealthAction.NOOP
    assert h.n_dead == 1            # duplicate didn't push beyond budget
    assert h.apply(recovery(2.0, 1)) is HealthAction.REENCODE
    assert h.apply(recovery(3.0, 1)) is HealthAction.NOOP


def test_runtime_config_validation():
    with pytest.raises(ValueError):
        RuntimeConfig(n_slots=0)
    with pytest.raises(ValueError):
        RuntimeConfig(step_time_ms=-1.0)


def test_arrival_time_survives_round_boundaries(coded):
    """run_arrivals must preserve the workload's true arrival instant so
    latency includes the sub-round wait before submission."""
    cfg, stepper = coded
    sched = _sched(stepper, n_slots=1)
    prompts = _prompts(cfg, 2)
    # second request arrives at 0.25 ms, mid-way through round [0, 1)
    done = run_arrivals(sched, [(0.0, prompts[0], 2),
                                (0.25, prompts[1], 2)])
    by_rid = {r.rid: r for r in done}
    assert by_rid[1].arrival_ms == 0.25
    assert by_rid[1].queueing_ms > 0.0


def test_event_kinds_and_validation():
    h = ShardHealthController(2, budget=1)
    with pytest.raises(ValueError):
        h.apply(erasure(0.0, 5))
    assert erasure(1.0, 0).kind is EventKind.ERASURE
    assert replica_failure(1.0).shard == -1


def test_sim_clock():
    c = SimClock()
    assert c.now() == 0.0
    c.advance(2.5)
    c.advance_to(2.0)          # no-op backwards
    assert c.now() == 2.5
    with pytest.raises(ValueError):
        c.advance(-1.0)


# ----------------------------------------------------- mesh placement ----

class _FakeMesh:
    """Duck-typed stand-in for jax.sharding.Mesh (the mapping helpers only
    read axis_names / shape / devices), so placement logic is unit-testable
    on a 1-device host; the real-mesh integration runs in the multidev
    subprocess suite."""

    def __init__(self, axes: dict):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)
        n = int(np.prod(list(axes.values())))
        self.devices = np.arange(n).reshape(tuple(axes.values()))


def test_health_maps_shards_onto_mesh_devices():
    mesh = _FakeMesh({"data": 2, "model": 4})
    h = ShardHealthController(4, budget=1)
    by_shard = h.shard_devices(mesh)
    # model-rank i holds shard i, once per data replica (column i)
    assert by_shard[2] == (2, 6)
    assert h.apply(erasure(0.0, 2)) is HealthAction.CONTINUE
    dmask = h.device_mask(mesh)
    assert dmask.shape == (2, 4)
    assert not dmask[:, 2].any() and dmask[:, [0, 1, 3]].all()
    assert h.dead_devices(mesh) == (2, 6)
    h.apply(recovery(1.0, 2))
    assert h.device_mask(mesh).all() and h.dead_devices(mesh) == ()


def test_health_mesh_mapping_respects_pod_axis_and_validates():
    mesh = _FakeMesh({"pod": 2, "data": 2, "model": 2})
    h = ShardHealthController(2, budget=1)
    h.apply(erasure(0.0, 1))
    # shard 1 = model-rank 1 in every (pod, data) replica: odd device ids
    assert h.dead_devices(mesh) == (1, 3, 5, 7)
    assert h.device_mask(mesh)[:, :, 0].all()
    with pytest.raises(ValueError):
        h.shard_devices(_FakeMesh({"data": 2, "model": 4}))  # T mismatch
    with pytest.raises(ValueError):
        h.device_mask(_FakeMesh({"data": 2, "rows": 2}))  # no model axis
