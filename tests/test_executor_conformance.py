"""Differential conformance: universal slot-batching across the model zoo.

PR 3 pinned batched ≡ sequential for decoder-only transformers; the
executor now runs EVERY zoo family — enc-dec (whisper, per-slot encoder
extras bank) and xLSTM (positionless block state, slot axis 0) included —
through the same one-jitted-dispatch-per-round path, and the sequential
per-slot stepper survives only as the oracle these tests pin against:

  (a) batched ≡ sequential token-for-token across staggered admission /
      eviction (slot reuse), in both overlap modes, per architecture;
  (b) every in-budget erasure index yields the identical token stream
      (scheduler level) and bit-close logits (round level) — the paper's
      close-to-zero recovery, pool-wide, for every family;
  (c) one decode round is ONE dispatch and ONE trace ever (``decode_one``
      is never touched on the hot path);
  (d) the Pallas fused coded-head fast path agrees with the reference
      round on the new families too;
  (e) property-based slot isolation: random admit→evict→requeue→heal
      sequences never leak encoder state or xLSTM block state between
      slot rows, and admission into a warm bank never retraces
      ``write_slot``.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback keeps the suite collecting everywhere
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import get_arch, smoke_config
from repro.models import TPCtx, build
from repro.runtime import (ContinuousBatchingScheduler, RuntimeConfig,
                           ShardHealthController, erasure, run_arrivals)
from repro.runtime.executor import (TRACES, SlotPoolExecutor, VStep,
                                    read_slot, slot_axis,
                                    supports_slot_batching)
from repro.serve import ModelStepper

GEN = 5
T, R = 4, 2
ZOO = ("granite-3-8b", "whisper-medium", "xlstm-125m")


@pytest.fixture(scope="module", params=ZOO)
def zoo(request):
    cfg = smoke_config(get_arch(request.param))
    model = build(cfg, TPCtx(tp=T, mode="coded", code_r=R, moe_capacity=0))
    params = model.init(jax.random.PRNGKey(0))
    stepper = ModelStepper(model, params, max_len=48)
    return cfg, stepper


def _extras(cfg, rng):
    """Per-request batch extras (enc-dec: fresh frames per request, so
    slots carry genuinely different encoder context)."""
    if not cfg.is_encdec:
        return None
    return {"frames": rng.normal(size=(cfg.enc_seq, cfg.d_model))
            .astype(np.float32)}


def _staggered(cfg, n, base_len=4, seed=3):
    """Prompts of different lengths arriving at different times — slots
    end up at genuinely different positions, and n > n_slots forces
    eviction + slot reuse mid-stream."""
    rng = np.random.default_rng(seed)
    return [(i * 1.5, rng.integers(0, cfg.vocab, base_len + i % 3), GEN,
             _extras(cfg, rng)) for i in range(n)]


def _serve(stepper, arrivals, *, batched, n_slots=4, overlap=True,
           events=(), use_fused="auto"):
    health = ShardHealthController(stepper.n_shards, stepper.erasure_budget,
                                   events=list(events))
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=n_slots, batched=batched,
                               overlap=overlap, use_fused=use_fused),
        health=health)
    done = run_arrivals(sched, arrivals)
    return sched, {r.rid: r.tokens for r in done}


# ------------------------------------------------ (a) zoo equivalence ----

def test_batched_is_default_for_every_family(zoo):
    cfg, stepper = zoo
    assert supports_slot_batching(stepper.model)
    sched = ContinuousBatchingScheduler(stepper, RuntimeConfig(n_slots=2))
    assert sched.executor is not None, \
        f"{cfg.name}: batched executor must be the default"


def test_batched_matches_sequential_staggered(zoo):
    """One dispatch per round ≡ the sequential oracle, token for token,
    across staggered admission and slot reuse — both overlap modes."""
    cfg, stepper = zoo
    arrivals = _staggered(cfg, 6)
    s_seq, toks_seq = _serve(stepper, arrivals, batched=False)
    assert s_seq.executor is None
    _, toks_b = _serve(stepper, arrivals, batched=True, overlap=True)
    _, toks_bn = _serve(stepper, arrivals, batched=True, overlap=False)
    assert len(toks_seq) == 6
    assert toks_b == toks_seq
    assert toks_bn == toks_seq
    assert all(len(t) == GEN for t in toks_b.values())


# ------------------------------------- (b) every in-budget erasure ----

def test_every_inbudget_erasure_stream_identical(zoo):
    """For EVERY erasable shard index: the batched stream under a
    mid-run erasure equals the fault-free stream (recovered in-step,
    nothing requeued) — and the sequential oracle agrees."""
    cfg, stepper = zoo
    arrivals = _staggered(cfg, 4)
    _, toks_ok = _serve(stepper, arrivals, batched=True)
    for shard in range(T):
        s_f, toks_f = _serve(stepper, arrivals, batched=True,
                             events=[erasure(2.0, shard)])
        assert toks_f == toks_ok, f"shard {shard}"
        assert s_f.metrics.counters["erasures_recovered"] == 1
        assert s_f.metrics.counters["requests_requeued"] == 0
    # oracle cross-check on one index
    _, toks_seq = _serve(stepper, arrivals, batched=False,
                         events=[erasure(2.0, 1)])
    assert toks_seq == toks_ok


def test_every_inbudget_erasure_exact_logits(zoo):
    """Round level: each single-shard erasure under the stacked round
    reproduces the fault-free logits for the whole pool at once."""
    cfg, stepper = zoo
    rng = np.random.default_rng(1)
    ex = SlotPoolExecutor(stepper, n_slots=4, overlap=False)
    full = np.ones(T, bool)
    for i, plen in enumerate((4, 6, 7, 5)):     # staggered positions
        ex.admit(i, rng.integers(0, cfg.vocab, plen), full, tag=i,
                 extras=_extras(cfg, rng))
    vstep = ex.vstep
    _, toks_ok, logits_ok = vstep.round(ex.state, ex.last_toks, full)
    assert logits_ok is not None
    for shard in range(T):
        mask = full.copy()
        mask[shard] = False
        _, toks_f, logits_f = vstep.round(ex.state, ex.last_toks, mask)
        np.testing.assert_allclose(np.asarray(logits_f),
                                   np.asarray(logits_ok),
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"shard {shard}")
        np.testing.assert_array_equal(np.asarray(toks_f),
                                      np.asarray(toks_ok))


# --------------------------------------- (c) one dispatch, one trace ----

def test_one_round_is_one_dispatch_one_trace(zoo):
    """The acceptance pin, per architecture: a decode round is ONE jitted
    dispatch for the whole pool, traced exactly once for the life of the
    run, with the per-slot ``decode_one`` stepper never touched."""
    cfg, stepper = zoo
    calls = {"decode_one": 0}
    orig = stepper.decode_one
    stepper.decode_one = lambda *a, **k: calls.__setitem__(
        "decode_one", calls["decode_one"] + 1) or orig(*a, **k)
    try:
        sched, toks = _serve(stepper, _staggered(cfg, 8), batched=True,
                             n_slots=4)
    finally:
        stepper.decode_one = orig
    assert calls["decode_one"] == 0, "per-slot Python-loop stepping on " \
                                     "the batched hot path"
    vstep = sched.executor.vstep
    assert vstep.n_traces == 1, "round retraced: admission/mask changed " \
                                "compiled shapes"
    assert vstep.n_dispatches == sched.metrics.counters["decode_rounds"]
    assert sched.metrics.counters["requests_completed"] == 8


# ----------------------------------------------- (d) fused fast path ----

def test_fused_round_matches_reference(zoo):
    """The Pallas fused coded-head round (body → hidden → head GEMM +
    Eq. 12 parity decode + argmax) agrees with the full-logits reference
    round on every family, fault-free and with one erased shard."""
    cfg, stepper = zoo
    rng = np.random.default_rng(5)
    ex = SlotPoolExecutor(stepper, n_slots=3, overlap=False)
    full = np.ones(T, bool)
    for i, plen in enumerate((4, 6, 5)):
        ex.admit(i, rng.integers(0, cfg.vocab, plen), full, tag=i,
                 extras=_extras(cfg, rng))
    ref_step = VStep(stepper, use_fused=False)
    fused_step = VStep(stepper, use_fused=True)
    assert fused_step.use_fused, \
        f"fused path must be available for coded {cfg.name}"
    for mask in (full, np.array([True, False, True, True])):
        _, toks_ref, _ = ref_step.round(ex.state, ex.last_toks, mask)
        _, toks_fused, logits = fused_step.round(ex.state, ex.last_toks,
                                                 mask)
        assert logits is None, "fused round must not materialise logits"
        np.testing.assert_array_equal(np.asarray(toks_fused),
                                      np.asarray(toks_ref))


# ------------------------------------- (d') fused IN-BODY kernels ----

def test_fused_body_scheduler_stream_matches(zoo):
    """Full-Pallas rounds (fused in-body coded GEMMs + fused head) under
    the real scheduler: the complete token stream equals the reference
    path token-for-token per arch, fault-free AND across every in-budget
    mid-run erasure, with the one-trace pin intact."""
    cfg, stepper = zoo
    arrivals = _staggered(cfg, 4)
    _, toks_ref = _serve(stepper, arrivals, batched=True, use_fused=False)
    s_fused, toks_fused = _serve(stepper, arrivals, batched=True,
                                 use_fused=True)
    assert toks_fused == toks_ref, f"{cfg.name}: fused-body stream diverged"
    assert s_fused.executor.vstep.use_fused
    assert s_fused.executor.vstep.n_traces == 1, \
        "fused round retraced mid-run"
    for shard in range(T):
        s_f, toks_f = _serve(stepper, arrivals, batched=True,
                             use_fused=True, events=[erasure(2.0, shard)])
        assert toks_f == toks_ref, \
            f"{cfg.name}: fused-body stream diverged under erasure of " \
            f"shard {shard}"
        assert s_f.metrics.counters["erasures_recovered"] == 1
        assert s_f.executor.vstep.n_traces == 1


def test_fused_one_round_is_one_dispatch_one_trace(zoo):
    """The (c) pin holds when the in-body kernels swap in: one jitted
    dispatch per round, one trace ever, ``decode_one`` untouched."""
    cfg, stepper = zoo
    calls = {"decode_one": 0}
    orig = stepper.decode_one
    stepper.decode_one = lambda *a, **k: calls.__setitem__(
        "decode_one", calls["decode_one"] + 1) or orig(*a, **k)
    try:
        sched, toks = _serve(stepper, _staggered(cfg, 6), batched=True,
                             n_slots=3, use_fused=True)
    finally:
        stepper.decode_one = orig
    assert calls["decode_one"] == 0
    vstep = sched.executor.vstep
    assert vstep.n_traces == 1
    assert vstep.n_dispatches == sched.metrics.counters["decode_rounds"]
    assert sched.metrics.counters["requests_completed"] == 6


def test_fused_multi_erasure_round_takes_reference_path():
    """Erasure-limit regression (satellite): the fused kernels cover <=1
    erased shard; a dedicated-layout round with TWO in-budget erasures
    must drop to the reference MDS path (full logits materialised) and
    still produce the reference tokens — graceful fallback, not a wrong
    answer."""
    cfg = smoke_config(get_arch("granite-3-8b"))
    model = build(cfg, TPCtx(tp=T, mode="coded", code_r=2,
                             code_layout="dedicated", moe_capacity=0))
    params = model.init(jax.random.PRNGKey(0))
    stepper = ModelStepper(model, params, max_len=32)
    assert stepper.erasure_budget == 2
    rng = np.random.default_rng(9)
    ex = SlotPoolExecutor(stepper, n_slots=2, overlap=False)
    full = np.ones(T, bool)
    for i in range(2):
        ex.admit(i, rng.integers(0, cfg.vocab, 5), full, tag=i)
    ref_step = VStep(stepper, use_fused=False)
    fused_step = VStep(stepper, use_fused=True)
    assert fused_step.use_fused
    mask2 = np.array([True, False, False, True])   # in budget (dedicated)
    _, toks_ref, logits_ref = ref_step.round(ex.state, ex.last_toks, mask2)
    _, toks_f, logits_f = fused_step.round(ex.state, ex.last_toks, mask2)
    assert logits_f is not None, \
        "2-erasure round must take the reference path (full logits)"
    np.testing.assert_array_equal(np.asarray(toks_f), np.asarray(toks_ref))
    np.testing.assert_allclose(np.asarray(logits_f),
                               np.asarray(logits_ref), rtol=1e-5, atol=1e-5)
    # and a 1-erasure round on the same VStep still takes the kernel
    mask1 = np.array([True, False, True, True])
    _, toks_f1, logits_f1 = fused_step.round(ex.state, ex.last_toks, mask1)
    assert logits_f1 is None
    _, toks_r1, _ = ref_step.round(ex.state, ex.last_toks, mask1)
    np.testing.assert_array_equal(np.asarray(toks_f1), np.asarray(toks_r1))


# --------------------------------- (e) property: slot isolation ----

def _snapshot(ex, slot):
    return [np.asarray(leaf) for leaf in
            jax.tree.leaves(read_slot(ex.state, slot, axis=ex.slot_axis))]


def _assert_rows_equal(a, b, msg):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y, err_msg=msg)


@settings(deadline=None, max_examples=12)
@given(ops=st.permutations(list(range(8))), seed=st.integers(0, 2 ** 16))
def test_slot_isolation_under_random_ops(zoo, ops, seed):
    """Random admit→evict→round→requeue→heal sequences on the stacked
    state (extras bank included): an operation targeting slot i leaves
    every other slot's row BIT-IDENTICAL — encoder state and xLSTM block
    state never leak between slots — and admission into the warm bank
    never retraces ``write_slot`` (trace delta asserted == 0)."""
    cfg, stepper = zoo
    rng = np.random.default_rng(seed)
    n_slots = 3
    ex = SlotPoolExecutor(stepper, n_slots=n_slots, overlap=False)
    mask = np.ones(T, bool)

    def admit(slot):
        ex.admit(slot, rng.integers(0, cfg.vocab, 4 + int(rng.integers(3))),
                 mask, tag=f"r{slot}", extras=_extras(cfg, rng))

    admit(0)                      # warm the write/read jit caches
    for s in range(n_slots):
        _snapshot(ex, s)
    write_traces0 = TRACES["write"]
    rows = {s: _snapshot(ex, s) for s in range(n_slots)}

    for op in ops:
        slot = int(rng.integers(n_slots))
        kind = ("admit", "evict", "round", "heal", "requeue")[op % 5]
        if kind == "admit":
            admit(slot)
            for other in range(n_slots):
                if other != slot:
                    _assert_rows_equal(
                        rows[other], _snapshot(ex, other),
                        f"{cfg.name}: admit({slot}) leaked into row "
                        f"{other}")
            rows[slot] = _snapshot(ex, slot)
        elif kind == "evict":
            ex.evict(slot)
        elif kind == "round":
            if ex.active.any():
                ex.step_round(mask)
                rows = {s: _snapshot(ex, s) for s in range(n_slots)}
        elif kind == "heal":
            stepper.reencode()    # params swap must not touch slot state
        else:
            ex.drop_pending()
            ex.evict_all()
        if kind in ("evict", "heal", "requeue"):
            for s in range(n_slots):
                _assert_rows_equal(rows[s], _snapshot(ex, s),
                                   f"{cfg.name}: {kind} mutated row {s}")

    assert TRACES["write"] == write_traces0, \
        f"{cfg.name}: write_slot retraced during admission into a warm bank"
