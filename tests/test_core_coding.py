"""Unit + property tests for the CDC coding algebra (paper §5.2-5.3, §7)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback keeps the suite collecting everywhere
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (CodeSpec, decode_outputs, encode_outputs,
                        encode_weights, generator_matrix,
                        max_decode_condition)

jax.config.update("jax_enable_x64", False)


def test_generator_r1_is_paper_sum_code():
    gen = generator_matrix(7, 1)
    np.testing.assert_allclose(gen, np.ones((1, 7)))


def test_generator_rows_and_conditioning():
    for t, r in [(4, 2), (8, 3), (16, 4), (16, 2)]:
        gen = generator_matrix(t, r)
        assert gen.shape == (r, t)
        cond = max_decode_condition(CodeSpec(t, r))
        assert np.isfinite(cond) and cond < 1e7, (t, r, cond)


def test_encode_weights_matches_paper_eq7():
    """W_cdc row = column sums of the stacked shard weights (Eq. 7/11)."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (4, 8, 16), jnp.float32)  # [T, k, m_l]
    spec = CodeSpec(4, 1)
    parity = encode_weights(w, spec)
    np.testing.assert_allclose(parity[0], w.sum(0), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("t,r,n_fail", [(2, 1, 1), (4, 1, 1), (8, 1, 0),
                                        (4, 2, 2), (8, 3, 3), (8, 3, 2),
                                        (16, 4, 4), (16, 2, 1)])
def test_decode_recovers_erasures(t, r, n_fail):
    key = jax.random.PRNGKey(t * 100 + r * 10 + n_fail)
    k1, k2 = jax.random.split(key)
    y = jax.random.normal(k1, (t, 3, 32), jnp.float32)
    spec = CodeSpec(t, r)
    parity = encode_outputs(y, spec)
    fail_idx = jax.random.choice(k2, t, (n_fail,), replace=False)
    valid = jnp.ones(t, bool).at[fail_idx].set(False)
    y_damaged = jnp.where(valid[:, None, None], y,
                          jnp.nan)  # garbage in erased slots
    y_damaged = jnp.nan_to_num(y_damaged, nan=1e9)
    rec = decode_outputs(y_damaged, parity, valid, spec)
    # fp32 tolerance scales with the decode submatrix conditioning (r big
    # => worse-conditioned Vandermonde solve); see DESIGN.md §8.
    tol = 2e-4 if r <= 2 else (2e-3 if n_fail <= 3 else 2e-2)
    np.testing.assert_allclose(rec, y, rtol=tol, atol=tol)


def test_decode_jit_and_grad_safe():
    spec = CodeSpec(4, 2)
    y = jnp.arange(4 * 6, dtype=jnp.float32).reshape(4, 6)
    parity = encode_outputs(y, spec)
    valid = jnp.array([True, False, True, True])

    f = jax.jit(lambda y, p, v: decode_outputs(y, p, v, spec).sum())
    assert np.isfinite(float(f(y, parity, valid)))
    g = jax.grad(lambda y: decode_outputs(
        y, encode_outputs(y, spec), valid, spec).sum())(y)
    assert np.all(np.isfinite(np.asarray(g)))


@settings(max_examples=40, deadline=None)
@given(
    t=st.integers(2, 12),
    r=st.integers(1, 3),
    data=st.data(),
)
def test_property_any_r_erasures_decode(t, r, data):
    """Property: for any (T, r <= T) and ANY erasure pattern of <= r shards,
    decode reproduces the original outputs (MDS property over the reals)."""
    r = min(r, t)
    n_fail = data.draw(st.integers(0, r))
    fail = sorted(data.draw(
        st.permutations(range(t)))[:n_fail]) if n_fail else []
    rng = np.random.default_rng(t * 1000 + r * 100 + n_fail)
    y = jnp.asarray(rng.standard_normal((t, 5, 4)), jnp.float32)
    spec = CodeSpec(t, r)
    parity = encode_outputs(y, spec)
    valid = jnp.ones(t, bool).at[jnp.asarray(fail, int)].set(
        False) if fail else jnp.ones(t, bool)
    y_damaged = y.at[jnp.asarray(fail, int)].set(123.456) if fail else y
    rec = decode_outputs(y_damaged, parity, valid, spec)
    np.testing.assert_allclose(rec, y, rtol=5e-3, atol=5e-3)


def test_parity_linearity_weights_vs_outputs():
    """Coding commutes with the GEMM: X @ W_cdc == sum_i gen[j,i] (X @ W_i).
    This is the property that lets the paper do the encode OFFLINE."""
    key = jax.random.PRNGKey(3)
    kx, kw = jax.random.split(key)
    T, k, m_l, b = 4, 12, 8, 5
    x = jax.random.normal(kx, (b, k), jnp.float32)
    w = jax.random.normal(kw, (T, k, m_l), jnp.float32)
    spec = CodeSpec(T, 2)
    w_parity = encode_weights(w, spec)                  # offline
    via_weights = jnp.einsum("bk,rkm->rbm", x, w_parity)
    ys = jnp.einsum("bk,tkm->tbm", x, w)
    via_outputs = encode_outputs(ys, spec)
    np.testing.assert_allclose(via_weights, via_outputs, rtol=1e-4, atol=1e-4)
