"""Tests for the coded GEMM layer: folded + dedicated layouts, conv, policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback keeps the suite collecting everywhere
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (TABLE_1, CodedDenseSpec, CodeSpec, coded_conv2d,
                        coded_matmul, conv2d_gemm, make_parity_weights,
                        pad_for_code, suitability_table)
from repro.core.coded_layer import folded_slot_map, unfold_parity, \
    fold_parity_slots


def _mk(key, T, r, k=16, m=None, batch=3, layout="folded"):
    m = m or T * T * 4
    kx, kw = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(kx, (batch, k), jnp.float32)
    w = jax.random.normal(kw, (k, m), jnp.float32) / np.sqrt(k)
    spec = CodedDenseSpec(CodeSpec(T, r), layout=layout)
    w_cdc = make_parity_weights(w, spec) if r else None
    return x, w, w_cdc, spec


def test_uncoded_path_is_plain_matmul():
    x, w, _, spec = _mk(0, T=4, r=0)
    y = coded_matmul(x, w, None, spec)
    np.testing.assert_allclose(y, x @ w, rtol=1e-5, atol=1e-5)


def test_all_valid_equals_plain_matmul():
    x, w, w_cdc, spec = _mk(1, T=4, r=2)
    valid = jnp.ones(4, bool)
    y = coded_matmul(x, w, w_cdc, spec, valid)
    np.testing.assert_allclose(y, x @ w, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("T,r", [(2, 2), (4, 2), (8, 2), (16, 2), (8, 4)])
def test_folded_recovers_single_device_failure(T, r):
    """The TPU-native layout: any ONE dead device (data shard + its folded
    parity slices both lost) is recovered exactly."""
    x, w, w_cdc, spec = _mk(2, T=T, r=r)
    ref = x @ w
    for dead in range(T):
        valid = jnp.ones(T, bool).at[dead].set(False)
        y = coded_matmul(x, w, w_cdc, spec, valid)
        np.testing.assert_allclose(y, ref, rtol=1e-3, atol=1e-3), dead


def test_folded_r4_recovers_two_device_failures():
    T, r = 8, 4
    x, w, w_cdc, spec = _mk(3, T=T, r=r)
    ref = x @ w
    for dead in [(0, 1), (2, 5), (6, 7), (0, 7)]:
        valid = jnp.ones(T, bool).at[jnp.asarray(dead)].set(False)
        y = coded_matmul(x, w, w_cdc, spec, valid)
        np.testing.assert_allclose(y, ref, rtol=2e-3, atol=2e-3), dead


def test_folded_r1_recovers_lost_message():
    """Paper's r=1 sum code under the message-erasure model: the data-shard
    message is lost but parity messages arrive."""
    T = 4
    x, w, w_cdc, spec = _mk(4, T=T, r=1)
    ref = x @ w
    for dead in range(T):
        valid = jnp.ones(T, bool).at[dead].set(False)
        y = coded_matmul(x, w, w_cdc, spec, valid,
                         valid_parity=jnp.ones(T, bool))
        np.testing.assert_allclose(y, ref, rtol=1e-3, atol=1e-3), dead


@pytest.mark.parametrize("T,r,nfail", [(4, 1, 1), (4, 2, 2), (8, 2, 2)])
def test_dedicated_layout_paper_scheme(T, r, nfail):
    """Paper-faithful +r-devices layout: parity on its own shard slots."""
    x, w, w_cdc, spec = _mk(5, T=T, r=r, layout="dedicated")
    ref = x @ w
    rng = np.random.default_rng(0)
    for _ in range(4):
        dead = rng.choice(T, nfail, replace=False)
        valid = jnp.ones(T, bool).at[jnp.asarray(dead)].set(False)
        y = coded_matmul(x, w, w_cdc, spec, valid)
        np.testing.assert_allclose(y, ref, rtol=5e-3, atol=5e-3)


def test_slot_map_stagger_property():
    """No device holds two parity slices protecting the same output column:
    failure of one device kills <= 1 equation per column."""
    for T, r in [(4, 2), (8, 3), (16, 4)]:
        smap = folded_slot_map(T, r)
        for s in range(T):
            slots = smap[:, s]
            assert len(set(slots.tolist())) == r, (T, r, s)
            # data shard s itself must not host a parity slice of column
            # block s... (it may; what matters is distinctness across j)


def test_fold_unfold_roundtrip():
    T, r, k, m_l = 8, 3, 5, 16
    parity = jnp.arange(r * k * m_l, dtype=jnp.float32).reshape(r, k, m_l)
    slots = fold_parity_slots(parity, T)  # [T, k, r*w]
    # simulate "outputs": identity input so outputs == weights
    back = unfold_parity(jnp.moveaxis(slots, 1, 1), T, r)
    np.testing.assert_allclose(back, parity)


def test_grad_flows_through_coded_matmul():
    x, w, w_cdc, spec = _mk(6, T=4, r=2)
    valid = jnp.ones(4, bool).at[1].set(False)

    def loss(w):
        w_cdc = make_parity_weights(w, spec)
        return coded_matmul(x, w, w_cdc, spec, valid).sum()

    g = jax.grad(loss)(w)
    assert np.all(np.isfinite(np.asarray(g)))


def test_pad_for_code():
    assert pad_for_code(100, 4, align=8) == 128
    assert pad_for_code(49155, 16, align=8) % (16 * 16 * 8) == 0
    assert pad_for_code(2048, 16, align=8) == 2048


@settings(max_examples=25, deadline=None)
@given(T=st.sampled_from([2, 4, 8]), dead=st.integers(0, 7),
       batch=st.integers(1, 4))
def test_property_folded_single_failure(T, dead, batch):
    dead = dead % T
    x, w, w_cdc, spec = _mk(7 + T, T=T, r=2, batch=batch)
    valid = jnp.ones(T, bool).at[dead].set(False)
    y = coded_matmul(x, w, w_cdc, spec, valid)
    np.testing.assert_allclose(y, x @ w, rtol=2e-3, atol=2e-3)


# ---- conv / channel splitting (paper Fig. 8: == output splitting) ----

def test_conv_gemm_matches_lax_conv():
    key = jax.random.PRNGKey(11)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (2, 8, 8, 3), jnp.float32)
    f = jax.random.normal(kw, (3, 3, 3, 8), jnp.float32)
    ours = conv2d_gemm(x, f)
    ref = jax.lax.conv_general_dilated(
        x, f, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(ours, ref, rtol=1e-4, atol=1e-4)


def test_coded_conv_channel_split_recovers():
    key = jax.random.PRNGKey(12)
    kx, kw = jax.random.split(key)
    T = 4
    x = jax.random.normal(kx, (2, 6, 6, 3), jnp.float32)
    filt = jax.random.normal(kw, (3, 3, 3, T * T * 2), jnp.float32)
    spec = CodedDenseSpec(CodeSpec(T, 2))
    w_cdc = make_parity_weights(
        filt.reshape(-1, filt.shape[-1]), spec)
    ref = conv2d_gemm(x, filt)
    for dead in range(T):
        valid = jnp.ones(T, bool).at[dead].set(False)
        y = coded_conv2d(x, filt, w_cdc, spec, valid)
        np.testing.assert_allclose(y, ref, rtol=1e-2, atol=1e-2)


# ---- Table 1 policy ----

def test_table1_reproduced():
    table = {row["method"]: row["suitable"] for row in suitability_table()}
    assert table == TABLE_1
