"""Deterministic stand-in for ``hypothesis`` when it is not installed.

Implements just the API surface this suite uses (``given``, ``settings``,
``strategies.integers/floats/sampled_from/permutations/data``). Each
``@given`` test runs a fixed number of seeded-random examples instead of
hypothesis' adaptive search, so the suite collects and exercises the same
properties everywhere — minus shrinking and example databases. Install
``hypothesis`` (see requirements-dev.txt) to get the real thing.
"""
from __future__ import annotations

import inspect
import random

N_EXAMPLES = 12


class Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> Strategy:
        return Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(values) -> Strategy:
        vals = list(values)
        return Strategy(lambda rng: rng.choice(vals))

    @staticmethod
    def permutations(values) -> Strategy:
        vals = list(values)

        def draw(rng):
            out = list(vals)
            rng.shuffle(out)
            return out

        return Strategy(draw)

    @staticmethod
    def data() -> Strategy:
        return Strategy(_DataObject)


class _DataObject:
    """Shares the example's rng so in-test draws stay deterministic."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: Strategy):
        return strategy.example(self._rng)


def given(**strategy_kwargs):
    def deco(fn):
        # Params not drawn from strategies are pytest fixtures: keep them
        # in the runner's signature so pytest injects them (hypothesis
        # supports the same mixing).
        fixture_params = [
            p for name, p in inspect.signature(fn).parameters.items()
            if name not in strategy_kwargs]

        def runner(*args, **fixtures):
            fixtures.update({p.name: a
                             for p, a in zip(fixture_params, args)})
            for i in range(N_EXAMPLES):
                rng = random.Random(0xC0DED + i)
                drawn = {k: s.example(rng)
                         for k, s in strategy_kwargs.items()}
                fn(**fixtures, **drawn)

        # NOT functools.wraps: copying __wrapped__ would make pytest
        # introspect fn's full signature and demand fixtures for every
        # strategy kwarg too.
        runner.__signature__ = inspect.Signature(fixture_params)
        for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
            setattr(runner, attr, getattr(fn, attr))
        return runner

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco
