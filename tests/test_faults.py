"""Fault-injection chaos harness + adaptive redundancy planner.

Tier-1 properties: the injector is deterministic under one root seed
(bit-exact replay), traces round-trip through files, the planner sizes r
from observed failure rates (never below observed concurrency, Table-1
gate respected), the injected latency process reflects the fault state,
and the runtime generates IDENTICAL tokens batched vs sequential under
an identical injected fault schedule.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.core.failure import StragglerModel
from repro.core.policy import INPUT_SPLIT
from repro.faults import (AdaptiveRedundancyPlanner, ChaosSpec,
                          FaultInjector, InjectedLatency, LatencySpec,
                          PlannerConfig, TraceInjector, attach_chaos,
                          attach_planner, binomial_tail, churn_trace,
                          load_trace, make_pi_rig_trace, parse_chaos,
                          required_budget, stream_rng, write_trace)
from repro.models import TPCtx, build
from repro.runtime import (ContinuousBatchingScheduler, EventKind,
                           RuntimeConfig, ShardHealthController,
                           run_arrivals)
from repro.serve import ModelStepper

GEN = 6
PROMPT_LEN = 8


def _ev_tuples(evs):
    return [(e.time_ms, e.kind, e.shard) for e in evs]


@pytest.fixture(scope="module")
def coded():
    cfg = smoke_config(get_arch("granite-3-8b"))
    model = build(cfg, TPCtx(tp=4, mode="coded", code_r=2, moe_capacity=0))
    params = model.init(jax.random.PRNGKey(0))
    stepper = ModelStepper(model, params, max_len=48)
    return cfg, stepper


def _fresh_stepper(code_r=2):
    cfg = smoke_config(get_arch("granite-3-8b"))
    model = build(cfg, TPCtx(tp=4, mode="coded", code_r=code_r,
                             moe_capacity=0))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ModelStepper(model, params, max_len=48)


def _prompts(cfg, n):
    rng = np.random.default_rng(7)
    return [rng.integers(0, cfg.vocab, PROMPT_LEN) for _ in range(n)]


# ----------------------------------------------------------- injector ----

def test_injector_deterministic_replay():
    spec = ChaosSpec(mtbf_ms=100, mttr_ms=20, p_permanent=0.1,
                     p_degraded=0.2, groups=2, burst_mtbf_ms=300)
    a = FaultInjector(spec, 4, seed=5)
    b = FaultInjector(spec, 4, seed=5)
    evs_a = _ev_tuples(a.events_until(800.0))
    evs_b = _ev_tuples(b.events_until(800.0))
    assert evs_a == evs_b and evs_a
    assert a.degraded == b.degraded
    c = FaultInjector(spec, 4, seed=6)
    evs_c = _ev_tuples(c.events_until(800.0))
    assert evs_c != evs_a or c.degraded != a.degraded
    # incremental pulls see the same schedule as one big pull
    d = FaultInjector(spec, 4, seed=5)
    inc = []
    for t in np.linspace(50.0, 800.0, 16):
        inc.extend(d.events_until(float(t)))
    assert _ev_tuples(inc) == evs_a
    with pytest.raises(ValueError):
        d.events_until(10.0)        # time must be monotone


def test_injector_event_structure():
    # pure transient churn: erasures and recoveries alternate per device
    inj = FaultInjector(ChaosSpec(mtbf_ms=50, mttr_ms=10), 3, seed=0)
    evs = inj.events_until(2000.0)
    assert evs and all(e.time_ms <= 2000.0 for e in evs)
    state = {d: True for d in range(3)}
    for e in evs:
        if e.kind is EventKind.ERASURE:
            assert state[e.shard], "erasure of an already-down device"
            state[e.shard] = False
        else:
            assert not state[e.shard]
            state[e.shard] = True
    # permanent-only: no device ever recovers, each dies at most once
    perm = FaultInjector(ChaosSpec(mtbf_ms=50, mttr_ms=10,
                                   p_permanent=1.0), 4, seed=1)
    evs = perm.events_until(5000.0)
    assert all(e.kind is EventKind.ERASURE for e in evs)
    assert len({e.shard for e in evs}) == len(evs) <= 4


def test_injector_correlated_bursts_and_degraded():
    spec = ChaosSpec(mtbf_ms=1e9, mttr_ms=10, groups=2, burst_mtbf_ms=200,
                     burst_down_ms=25)
    inj = FaultInjector(spec, 4, seed=3)
    evs = inj.events_until(3000.0)
    erasures = [e for e in evs if e.kind is EventKind.ERASURE]
    assert erasures, "bursts must fire"
    # a burst takes a whole AP group down at the same instant
    by_time = {}
    for e in erasures:
        by_time.setdefault(e.time_ms, []).append(e.shard)
    assert any(len(shards) == 2 for shards in by_time.values())
    for shards in by_time.values():
        groups = {d % 2 for d in shards}
        assert len(groups) == 1, "burst crossed AP groups"
    # degraded-only churn: no mask flips, slowdown visible mid-interval
    deg = FaultInjector(ChaosSpec(mtbf_ms=40, mttr_ms=20, p_degraded=1.0,
                                  degraded_factor=7.0), 2, seed=0)
    assert deg.events_until(1000.0) == []
    assert deg.degraded
    t0, t1, d, f = deg.degraded[0]
    slow = deg.slowdown_at((t0 + t1) / 2)
    assert slow[d] == 7.0 and f == 7.0


def test_trace_roundtrip_and_playback(tmp_path):
    records = make_pi_rig_trace(horizon_ms=1500.0, n_shards=12, seed=2)
    path = tmp_path / "rig.jsonl"
    write_trace(str(path), records)
    assert load_trace(str(path)) == records
    inj = TraceInjector.from_file(str(path), 12)
    evs = inj.events_until(1500.0)
    mask_events = [r for r in records if r["kind"] != "degraded"]
    assert len(evs) == len(mask_events)
    assert inj.events_until(1500.0) == []          # consumed exactly once
    # playback onto a smaller rig than the trace was recorded for fails
    with pytest.raises(ValueError):
        TraceInjector(records, 4)


def test_trace_degraded_records_validated():
    rec = {"t_ms": 0.0, "kind": "degraded", "shard": 7, "until_ms": 5.0}
    with pytest.raises(ValueError):
        TraceInjector([rec], 4)
    with pytest.raises(ValueError):       # missing shard must not default
        TraceInjector([{"t_ms": 0.0, "kind": "degraded",
                        "until_ms": 5.0}], 4)


def test_permanent_death_resumes_churn_after_replica_swap():
    from repro.faults.injector import DEAD, UP
    inj = FaultInjector(ChaosSpec(mtbf_ms=50, mttr_ms=10,
                                  p_permanent=1.0), 2, seed=0)
    evs = inj.events_until(2000.0)
    assert evs and all(e.kind is EventKind.ERASURE for e in evs)
    assert (inj.state == DEAD).all()
    # in-budget permanent death (shard still masked dead): stays retired
    still_dead = np.array([False, True])
    inj.sync_replaced(still_dead, 2000.0)
    assert inj.state[0] == DEAD and inj.state[1] == UP
    # 2MR replica swap healed everything: churn must resume on the standby
    inj.sync_replaced(np.ones(2, bool), 2000.0)
    assert (inj.state == UP).all()
    assert inj.events_until(100000.0), \
        "replaced hardware must experience faults again"


def test_churn_trace_stays_in_budget():
    rec = churn_trace(4, 0.0, 1000.0, period_ms=100.0, down_ms=40.0,
                      concurrent=2)
    down, max_down = set(), 0
    for r in sorted(rec, key=lambda r: (r["t_ms"], r["kind"] != "erasure")):
        if r["kind"] == "erasure":
            down.add(r["shard"])
        else:
            down.discard(r["shard"])
        max_down = max(max_down, len(down))
    assert max_down == 2
    with pytest.raises(ValueError):
        churn_trace(4, 0.0, 100.0, period_ms=50.0, down_ms=60.0)


def test_parse_chaos(tmp_path):
    inj = parse_chaos("weibull:mtbf=300,mttr=40,p_perm=0.05,groups=2,"
                      "burst_mtbf=500", 4, seed=1)
    assert isinstance(inj, FaultInjector)
    assert inj.spec.fail_dist == "weibull"
    assert inj.spec.mtbf_ms == 300 and inj.spec.p_permanent == 0.05
    assert inj.spec.groups == 2
    path = tmp_path / "t.jsonl"
    write_trace(str(path), churn_trace(4, 0.0, 100.0, 50.0, 20.0))
    assert isinstance(parse_chaos(str(path), 4), TraceInjector)
    with pytest.raises(ValueError):
        parse_chaos("exp:bogus=1", 4)
    with pytest.raises(ValueError):
        parse_chaos("gauss:mtbf=10", 4)


def test_stream_rng_independence():
    a, b = stream_rng(0, "injector"), stream_rng(0, "latency")
    assert a.random(4).tolist() != b.random(4).tolist()
    assert stream_rng(0, "injector").random(4).tolist() == \
        stream_rng(0, "injector").random(4).tolist()


# ------------------------------------------------------------ planner ----

def test_binomial_tail_and_required_budget():
    assert binomial_tail(4, 0.0, 0) == 0.0
    assert binomial_tail(4, 1.0, 3) == 1.0
    assert binomial_tail(4, 1.0, 4) == 0.0
    p = 0.1
    assert binomial_tail(2, p, 0) == pytest.approx(1 - (1 - p) ** 2)
    assert required_budget(4, 0.0, 0.999, 4) == 0
    assert required_budget(4, 0.001, 0.999, 4) == 1
    assert required_budget(4, 0.9, 0.999999, 2) == 2   # capped at b_max


def test_planner_raises_and_lowers_with_cooldown():
    cfg = PlannerConfig(window_ms=10.0, min_budget=1, max_budget=2,
                        ewma=1.0, cooldown_windows=2)
    p = AdaptiveRedundancyPlanner(cfg, 4, layout="folded")
    two_dead = np.array([False, False, True, True])
    healthy = np.ones(4, bool)
    for t in range(11):
        p.observe_round(float(t), two_dead)
    plan = p.maybe_plan(11.0)
    assert plan is not None and plan.budget == 2 and plan.r == 4
    assert plan.window_max_dead == 2

    def calm_window(t0):
        for t in range(11):
            p.observe_round(t0 + t, healthy)
        return p.maybe_plan(t0 + 11.0)

    first = calm_window(20.0)
    assert first.budget == 2, "one calm window must not strip redundancy"
    second = calm_window(40.0)
    assert second.budget == 1 and second.r == 2, \
        "two calm windows should lower r"
    # mid-window polls return None
    p.observe_round(60.0, healthy)
    assert p.maybe_plan(60.5) is None


def test_planner_floors_at_observed_concurrency():
    """Even when the rate estimate says calm, the plan never drops below
    what actually happened in the window."""
    cfg = PlannerConfig(window_ms=10.0, min_budget=1, max_budget=2,
                        ewma=0.01)   # rate estimate barely moves
    p = AdaptiveRedundancyPlanner(cfg, 4)
    healthy = np.ones(4, bool)
    for t in range(10):
        p.observe_round(float(t), healthy)
    h = ShardHealthController(4, budget=1)
    from repro.runtime import erasure
    h.apply(erasure(5.0, 0))
    h.apply(erasure(5.1, 1))       # beyond budget: peak_dead = 2
    h.replace_replica()
    plan = p.maybe_plan(11.0, health=h)
    assert plan.budget == 2, "observed 2 concurrent dead must floor the plan"


def test_planner_table1_gate_routes_to_2mr():
    cfg = PlannerConfig(window_ms=10.0, min_budget=1, max_budget=2)
    p = AdaptiveRedundancyPlanner(cfg, 4, suitable=False)
    dead = np.array([False, False, True, True])
    for t in range(11):
        p.observe_round(float(t), dead)
    plan = p.maybe_plan(11.0)
    assert plan.r == 0, "unsuitable split cannot carry parity"
    assert plan.standby_replicas == 2, "tolerance must come from 2MR"


def test_health_set_budget_respects_table1_gate():
    h = ShardHealthController(4, budget=1)
    h.set_budget(2)
    assert h.budget == 2
    gated = ShardHealthController(4, budget=2, split=INPUT_SPLIT)
    gated.set_budget(3)
    assert gated.budget == 0
    with pytest.raises(ValueError):
        h.set_budget(-1)


# ------------------------------------------------------------ latency ----

class _StillInjector:
    def __init__(self, n_shards, factors=None):
        self.n_shards = n_shards
        self._f = np.ones(n_shards) if factors is None else \
            np.asarray(factors, float)

    def slowdown_at(self, t_ms):
        return self._f.copy()


def test_injected_latency_reflects_fault_state():
    spec = LatencySpec(base=StragglerModel(floor_ms=10.0, mu=0.0,
                                           sigma=0.3), timeout_ms=500.0)
    T, r = 4, 2
    healthy = InjectedLatency(spec, _StillInjector(T), seed=0)
    dt_h = healthy.round_ms(0.0, T, r, mask=np.ones(T, bool))
    assert 10.0 < dt_h < 500.0
    # same seed => same draws: an in-budget death only moves the order
    # statistic, it never stalls the round
    dead = InjectedLatency(spec, _StillInjector(T), seed=0)
    mask = np.ones(T, bool)
    mask[1] = False
    dt_d = dead.round_ms(0.0, T, r, mask=mask)
    assert dt_d < 500.0 and dt_d >= dt_h
    # uncoded (r=0) with a dead device stalls to the timeout
    unc = InjectedLatency(spec, _StillInjector(T), seed=0)
    assert unc.round_ms(0.0, T, 0, mask=mask) == 500.0
    # degraded devices inflate the round; replay is bit-exact
    slow = InjectedLatency(spec, _StillInjector(T, [50.0] * T), seed=0)
    assert slow.round_ms(0.0, T, r, mask=np.ones(T, bool)) > dt_h
    again = InjectedLatency(spec, _StillInjector(T), seed=0)
    assert again.round_ms(0.0, T, r, mask=np.ones(T, bool)) == dt_h


# ----------------------------------------------- runtime under chaos ----

def _chaos_sched(stepper, trace, *, batched=None, n_slots=2):
    injector = TraceInjector(trace, stepper.n_shards)
    health = ShardHealthController(stepper.n_shards,
                                   stepper.erasure_budget)
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=n_slots, batched=batched),
        health=health)
    attach_chaos(sched, injector)
    return sched


def test_batched_equals_sequential_under_identical_fault_schedule(coded):
    """The acceptance property: one fault schedule, both executors,
    token-for-token identical output."""
    cfg, stepper = coded
    prompts = _prompts(cfg, 4)
    trace = churn_trace(4, 2.0, 40.0, period_ms=8.0, down_ms=3.0,
                        concurrent=1)

    def serve(batched):
        sched = _chaos_sched(stepper, trace, batched=batched)
        done = run_arrivals(sched, [(i * 1.5, p, GEN)
                                    for i, p in enumerate(prompts)])
        assert len(done) == 4
        assert sched.metrics.counters["faults_injected"] > 0
        return ({r.rid: r.tokens for r in done},
                dict(sched.metrics.counters))

    toks_b, counters_b = serve(True)
    toks_s, counters_s = serve(False)
    assert toks_b == toks_s
    # round counts (and hence how far into the schedule each run pulls)
    # legitimately differ by the overlap drain round; what must agree is
    # that BOTH paths recovered erasures in-step and lost nothing
    assert counters_b["erasures_recovered"] > 0
    assert counters_s["erasures_recovered"] > 0
    assert counters_b["beyond_budget_failures"] == \
        counters_s["beyond_budget_failures"] == 0


def test_chaos_run_replays_bit_exact(coded):
    """One root seed drives stragglers + injector + latency: two runs are
    identical except the measured wall-clock series."""
    cfg, stepper = coded
    prompts = _prompts(cfg, 3)
    spec = ChaosSpec(mtbf_ms=400.0, mttr_ms=80.0, p_degraded=0.2)

    def once():
        injector = FaultInjector(spec, stepper.n_shards, seed=9)
        latency = InjectedLatency(
            LatencySpec(base=StragglerModel(floor_ms=2.0, mu=0.0,
                                            sigma=0.5)), injector, seed=9)
        health = ShardHealthController(stepper.n_shards,
                                       stepper.erasure_budget)
        sched = ContinuousBatchingScheduler(
            stepper, RuntimeConfig(n_slots=2, seed=9), health=health,
            latency=latency)
        attach_chaos(sched, injector)
        done = run_arrivals(sched, [(i * 3.0, p, GEN)
                                    for i, p in enumerate(prompts)])
        return {r.rid: r.tokens for r in done}, sched.metrics.snapshot()

    toks_a, snap_a = once()
    toks_b, snap_b = once()
    assert toks_a == toks_b
    meas_a = snap_a.pop("round_latency_measured")
    meas_b = snap_b.pop("round_latency_measured")
    assert meas_a["n"] == meas_b["n"] > 0
    assert snap_a == snap_b


def test_in_budget_chaos_loses_nothing_and_tokens_match(coded):
    cfg, stepper = coded
    prompts = _prompts(cfg, 4)
    arrivals = [(i * 2.0, p, GEN) for i, p in enumerate(prompts)]

    base = _chaos_sched(stepper, [])
    toks_base = {r.rid: r.tokens for r in run_arrivals(base, arrivals)}

    trace = churn_trace(4, 1.0, 60.0, period_ms=10.0, down_ms=4.0,
                        concurrent=1)
    sched = _chaos_sched(stepper, trace)
    toks = {r.rid: r.tokens for r in run_arrivals(sched, arrivals)}
    c = sched.metrics.counters
    assert toks == toks_base
    assert c["requests_completed"] == 4
    assert c["beyond_budget_failures"] == 0
    assert c["erasures_recovered"] > 0


# ---------------------------------------------- adaptive replanning ----

def test_set_code_r_reencodes_and_resizes_budget():
    cfg, stepper = _fresh_stepper(code_r=2)
    assert stepper.erasure_budget == 1
    old_cdc_shape = np.asarray(
        stepper.params["lm_head"]["cdc"]).shape
    assert stepper.set_code_r(4)
    assert stepper.erasure_budget == 2
    assert int(stepper.model.ctx.code_r) == 4
    new_cdc_shape = np.asarray(stepper.params["lm_head"]["cdc"]).shape
    assert new_cdc_shape != old_cdc_shape
    assert not stepper.set_code_r(4)     # no-op at the same geometry
    # decode still works at the new geometry, with 2 erasures recovered
    sched = ContinuousBatchingScheduler(stepper, RuntimeConfig(n_slots=1))
    from repro.runtime import erasure
    sched.health.set_budget(stepper.erasure_budget)
    sched.health.schedule(erasure(1.0, 0))
    sched.health.schedule(erasure(1.5, 3))
    rng = np.random.default_rng(3)
    done = run_arrivals(sched, [(0.0, rng.integers(0, cfg.vocab,
                                                   PROMPT_LEN), GEN)])
    assert len(done) == 1 and len(done[0].tokens) == GEN
    assert sched.metrics.counters["beyond_budget_failures"] == 0


def test_adaptive_planner_raises_and_lowers_r_end_to_end():
    """Calm -> storm (2 concurrent dead > budget) -> calm: the planner
    raises r via heal+re-encode, the storm then recovers in-step, and r
    comes back down after the cooldown. No request is lost."""
    cfg, stepper = _fresh_stepper(code_r=2)
    trace = churn_trace(4, 20.0, 80.0, period_ms=8.0, down_ms=3.0,
                        concurrent=2)
    sched = _chaos_sched(stepper, trace, n_slots=2)
    planner = AdaptiveRedundancyPlanner(
        PlannerConfig(window_ms=10.0, min_budget=1, max_budget=2,
                      cooldown_windows=2), stepper.n_shards,
        layout=stepper.model.ctx.code_layout)
    attach_planner(sched, planner)
    rng = np.random.default_rng(5)
    arrivals = [(i * 10.0, rng.integers(0, cfg.vocab, PROMPT_LEN), GEN)
                for i in range(14)]
    done = run_arrivals(sched, arrivals)
    c = sched.metrics.counters
    snap = sched.metrics.snapshot()
    rs = [r for _, r in snap["planner"]["r_series"]]
    assert len(done) == 14, "adaptive run lost a request"
    assert max(rs) == 4, f"planner never raised r: {rs}"
    assert rs[0] == 2 and rs[-1] == 2, f"r did not return to calm: {rs}"
    assert c["replans"] >= 2
    # converged budget covers the worst observed concurrency
    assert max(p["budget"] for p in sched.metrics.plan_log) >= 2
    # once raised, later storm waves recover in-step (CDC path)
    assert c["erasures_recovered"] > 0
    assert sched.health.budget == stepper.erasure_budget


def test_apply_plan_never_shrinks_below_live_dead_shards():
    from repro.faults import RedundancyPlan, apply_plan
    from repro.runtime import erasure
    cfg, stepper = _fresh_stepper(code_r=4)
    sched = ContinuousBatchingScheduler(stepper, RuntimeConfig(n_slots=1))
    sched.health.set_budget(stepper.erasure_budget)     # budget 2
    sched.health.apply(erasure(0.0, 0))
    sched.health.apply(erasure(0.5, 1))                 # 2 dead, in budget
    plan = RedundancyPlan(t_ms=1.0, budget=1, r=2, standby_replicas=1,
                          est_unavailability=0.0, window_max_dead=0,
                          reason="test")
    apply_plan(sched, plan)
    # 2 shards are dead: the code must keep covering them
    assert stepper.erasure_budget >= 2
    assert int(stepper.model.ctx.code_r) == 4
