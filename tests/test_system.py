"""End-to-end behaviour test for the paper's system (the elevator pitch).

One test that walks the paper's whole claim chain on a real model:
offline encode -> distributed coded serving -> mid-request failure ->
identical output, constant hardware cost, straggler improvement.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core import TABLE_1, suitability_table
from repro.core.failure import StragglerModel, coverage_2mr
from repro.models import TPCtx, build
from repro.serve import ServeConfig, ServingEngine


def test_paper_system_end_to_end():
    T = 4
    cfg = smoke_config(get_arch("granite-3-8b"))
    ctx = TPCtx(tp=T, mode="coded", code_r=2, moe_capacity=0)
    model = build(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))

    # 1. the paper's offline encode (weights-only, before deployment)
    params = model.encode_offline(params)

    # 2. coded serving: a shard dies mid-request; "the system never loses
    #    a request" — tokens are identical to the fault-free run
    scfg = ServeConfig(max_len=48, batch=2, cache_dtype=jnp.float32)
    prompts = model.dummy_batch(jax.random.PRNGKey(1), 2, 8)
    ok = ServingEngine(model, params, scfg).generate(prompts, 8)
    eng = ServingEngine(model, params, scfg)
    failed = eng.generate(prompts, 8, fail_at={2: 1})
    np.testing.assert_array_equal(ok, failed)
    assert eng.metrics["erasures_recovered"] == 1

    # 3. constant cost: one parity covers ALL T devices of the layer
    #    ((1+1/N)x, paper §6.3) vs 2x for modular redundancy
    econ = coverage_2mr(n_model_parallel=T, n_other=0)
    assert econ["hw_cost_cdc_2mr"] == 1 + 1 / T
    assert econ["hw_cost_2mr"] == 2.0

    # 4. straggler mitigation: first-T-of-(T+r) strictly improves latency
    stats = eng.straggler_latency(StragglerModel(), n_trials=4000)
    assert stats["mean_coded_ms"] < stats["mean_uncoded_ms"]

    # 5. Table 1 reproduced by the policy predicate
    assert {r["method"]: r["suitable"]
            for r in suitability_table()} == TABLE_1
