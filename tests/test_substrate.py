"""Substrate tests: optimizer, checkpoint, data pipeline, HLO cost model."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import AsyncCheckpointer, latest_step, restore, save
from repro.data import DataConfig, make_stream, write_corpus
from repro.optim import AdamWConfig, apply_updates, init_state, lr_at
from repro.roofline.hlo_cost import analyze_hlo


# ------------------------------------------------------------- optimizer ----

def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, schedule="constant")
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_state(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        return apply_updates(cfg, params, g, state)

    for _ in range(150):
        params, state, metrics = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_lr_schedule_warmup_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(lr_at(cfg, jnp.asarray(110))) - 0.1) < 1e-3


def test_grad_clip():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = init_state(params)
    g = {"w": jnp.full(3, 100.0)}
    _, _, metrics = apply_updates(cfg, params, g, state)
    assert float(metrics["grad_norm"]) > 100.0  # pre-clip norm reported


def test_no_decay_on_1d_params():
    cfg = AdamWConfig(lr=1e-3, weight_decay=0.1, grad_clip=0.0,
                      warmup_steps=0, schedule="constant")
    params = {"g": jnp.ones(4), "w": jnp.ones((4, 4))}
    state = init_state(params)
    g = jax.tree.map(jnp.zeros_like, params)
    newp, _, _ = apply_updates(cfg, params, g, state)
    np.testing.assert_allclose(newp["g"], params["g"])  # no decay on vector
    assert float(jnp.abs(newp["w"] - 1.0).max()) > 1e-6  # matrix decayed


# ------------------------------------------------------------ checkpoint ----

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)},
            "lst": [jnp.zeros(2), jnp.ones(3)]}
    d = str(tmp_path / "ck")
    save(tree, d, 7)
    assert latest_step(d) == 7
    out = restore(jax.tree.map(jnp.zeros_like, tree), d)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path / "ck")
    save({"x": jnp.ones(3)}, d, 1)
    assert not any(f.endswith(".tmp") for f in os.listdir(d))


def test_checkpoint_parity_reencoded(tmp_path):
    """Parity ('cdc') leaves are dropped on save and re-encoded on load —
    the paper's offline encode at weight-load time."""
    from repro.models.common import TPCtx, linear_init
    ctx = TPCtx(tp=4, mode="coded", code_r=2)
    lin = linear_init(jax.random.PRNGKey(0), 8, 64, ctx, jnp.float32)
    d = str(tmp_path / "ck")
    save({"lin": lin}, d, 1)
    # no parity file on disk
    files = os.listdir(os.path.join(d, "step_00000001"))
    assert not any("cdc" in f for f in files)
    tmpl = jax.tree.map(jnp.zeros_like, {"lin": lin})
    out = restore(tmpl, d, encode_ctx=ctx)
    np.testing.assert_allclose(out["lin"]["w"], lin["w"])
    np.testing.assert_allclose(out["lin"]["cdc"], lin["cdc"], rtol=1e-6)


def test_async_checkpointer_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    ck = AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3, 4):
        ck.save({"x": jnp.full(2, float(s))}, s)
    ck.close()
    assert latest_step(d) == 4
    steps = sorted(f for f in os.listdir(d) if f.startswith("step_"))
    assert len(steps) == 2  # gc kept last 2


def test_elastic_restore_shape_preserved(tmp_path):
    """The same checkpoint restores regardless of the process's mesh — the
    arrays are global; placement is the restore caller's choice."""
    d = str(tmp_path / "ck")
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save(tree, d, 1)
    out = restore({"w": jnp.zeros((8, 8))}, d)
    np.testing.assert_allclose(out["w"], tree["w"])


# ------------------------------------------------------------------ data ----

def test_data_deterministic_and_restartable():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8)
    a = [next(make_stream(cfg, i))["tokens"] for i in range(3)]
    b = list(x["tokens"] for _, x in zip(range(3), make_stream(cfg, 0)))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_data_host_sharding_disjoint():
    c0 = DataConfig(vocab=100, seq_len=8, global_batch=8, host_index=0,
                    host_count=2)
    c1 = DataConfig(vocab=100, seq_len=8, global_batch=8, host_index=1,
                    host_count=2)
    b0 = next(make_stream(c0))["tokens"]
    b1 = next(make_stream(c1))["tokens"]
    assert b0.shape == (4, 8) and b1.shape == (4, 8)
    assert not np.array_equal(b0, b1)


def test_memmap_corpus(tmp_path):
    path = str(tmp_path / "corpus.bin")
    write_corpus(path, vocab=97, n_tokens=10_000)
    cfg = DataConfig(vocab=97, seq_len=32, global_batch=4, kind="memmap",
                     path=path)
    batch = next(make_stream(cfg))["tokens"]
    assert batch.shape == (4, 32)
    assert batch.max() < 97 and batch.min() >= 0


# ------------------------------------------------------------- hlo cost ----

def test_hlo_cost_counts_scan_trips():
    w = jnp.zeros((128, 128), jnp.float32)

    def body(x, _):
        return x @ w, None

    f = jax.jit(lambda x: jax.lax.scan(body, x, None, length=7)[0])
    txt = f.lower(jnp.zeros((128, 128), jnp.float32)).compile().as_text()
    r = analyze_hlo(txt)
    assert abs(r["flops"] - 7 * 2 * 128 ** 3) / (7 * 2 * 128 ** 3) < 0.01


def test_hlo_cost_nested_scan():
    w = jnp.zeros((64, 64), jnp.float32)

    def inner(x, _):
        return x @ w, None

    def outer(x, _):
        return jax.lax.scan(inner, x, None, length=3)[0], None

    f = jax.jit(lambda x: jax.lax.scan(outer, x, None, length=5)[0])
    txt = f.lower(jnp.zeros((64, 64), jnp.float32)).compile().as_text()
    r = analyze_hlo(txt)
    want = 15 * 2 * 64 ** 3
    assert abs(r["flops"] - want) / want < 0.01
