"""Property tests for the dist layer: the coded GEMM is a drop-in GEMM.

Two tiers:
  * in-process (1 device): ``core.coded_matmul`` == plain ``x @ w`` across
    random shapes, T in {2, 4}, r in {1, 2}, both layouts, and every
    erasure mask within the layout's budget;
  * subprocess (8 fake devices, ``multidev``): the same property loop with
    the explicit shard_map path in the triangle —
    ``coded_matmul_shardmap`` == ``core.coded_matmul`` == ``x @ w``.

Uses real hypothesis when installed, else the deterministic shim.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback keeps the suite collecting everywhere
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import CodedDenseSpec, CodeSpec, coded_matmul, \
    make_parity_weights

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _case(b, k, mult, T, r, layout, n_dead, perm):
    """Build one random coded-GEMM case with <= budget erasures."""
    code = CodeSpec(T, r)
    spec = CodedDenseSpec(code, layout=layout)
    m = T * T * mult * 2  # folded slices need m % T^2 == 0
    kx, kw = jax.random.split(jax.random.PRNGKey(b * 1000 + k))
    x = jax.random.normal(kx, (b, k))
    w = jax.random.normal(kw, (k, m)) / max(k, 1) ** 0.5
    w_cdc = make_parity_weights(w, spec)
    dead = perm[:min(n_dead, spec.max_device_failures)]
    valid = jnp.ones(T, bool)
    for d in dead:
        valid = valid.at[d].set(False)
    return spec, x, w, w_cdc, valid


@settings(max_examples=16, deadline=None)
@given(b=st.integers(1, 5), k=st.integers(1, 40), mult=st.integers(1, 3),
       T=st.sampled_from([2, 4]), r=st.sampled_from([1, 2]),
       layout=st.sampled_from(["folded", "dedicated"]), data=st.data())
def test_coded_matmul_is_a_gemm_under_erasures(b, k, mult, T, r, layout,
                                               data):
    perm = data.draw(st.permutations(list(range(T))))
    n_dead = data.draw(st.integers(0, r))
    spec, x, w, w_cdc, valid = _case(b, k, mult, T, r, layout, n_dead, perm)
    got = coded_matmul(x, w, w_cdc, spec, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.multidev
def test_shardmap_triple_equivalence_properties():
    """Subprocess (8 fake devices): shard_map == logical == plain GEMM for
    random shapes, T in {2,4}, r in {1,2}, masks within budget."""
    code = textwrap.dedent("""
        import sys
        sys.path.insert(0, {tests!r})
        try:
            from hypothesis import given, settings, strategies as st
        except ImportError:
            from _hypothesis_fallback import given, settings, \\
                strategies as st
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import CodedDenseSpec, CodeSpec, coded_matmul, \\
            make_parity_weights
        from repro.dist.collectives import coded_matmul_shardmap

        assert len(jax.devices()) == 8
        MESHES = {{2: jax.make_mesh((4, 2), ("data", "model")),
                   4: jax.make_mesh((2, 4), ("data", "model"))}}

        @settings(max_examples=10, deadline=None)
        @given(b=st.integers(1, 5), k=st.integers(1, 40),
               mult=st.integers(1, 2), T=st.sampled_from([2, 4]),
               r=st.sampled_from([1, 2]),
               layout=st.sampled_from(["folded", "dedicated"]),
               data=st.data())
        def prop(b, k, mult, T, r, layout, data):
            code = CodeSpec(T, r)
            spec = CodedDenseSpec(code, layout=layout)
            m = T * T * mult * 2
            kx, kw = jax.random.split(jax.random.PRNGKey(b * 1000 + k))
            x = jax.random.normal(kx, (b, k))
            w = jax.random.normal(kw, (k, m)) / max(k, 1) ** 0.5
            w_cdc = make_parity_weights(w, spec)
            perm = data.draw(st.permutations(list(range(T))))
            n_dead = data.draw(st.integers(0, r))
            valid = jnp.ones(T, bool)
            for d in perm[:min(n_dead, spec.max_device_failures)]:
                valid = valid.at[d].set(False)
            got = coded_matmul_shardmap(x, w, w_cdc, spec, valid,
                                        mesh=MESHES[T])
            logical = coded_matmul(x, w, w_cdc, spec, valid)
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(logical),
                                       rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w),
                                       rtol=2e-3, atol=2e-3)

        prop()
        print("OK")
    """).format(tests=os.path.join(REPO, "tests"))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
