"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback keeps the suite collecting everywhere
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 384),
                                   (128, 512, 256), (384, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(m, k, n, dtype):
    k1, k2 = jax.random.split(KEY)
    x, w = _rand(k1, (m, k), dtype), _rand(k2, (k, n), dtype)
    got = ops.matmul(x, w)
    want = ref.matmul_ref(x, w)
    # fp32: accumulation-order differences across K blocks, ~eps*sqrt(K)
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_matmul_nonaligned_blocks():
    """Block sizes clamp to dims when the matrix is smaller than a tile."""
    k1, k2 = jax.random.split(KEY)
    x, w = _rand(k1, (64, 32), jnp.float32), _rand(k2, (32, 64), jnp.float32)
    np.testing.assert_allclose(ops.matmul(x, w), ref.matmul_ref(x, w),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("t,r", [(4, 1), (8, 2), (16, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cdc_encode(t, r, dtype):
    from repro.core.coding import generator_matrix
    k1, _ = jax.random.split(KEY)
    w = _rand(k1, (t, 256, 512), dtype)
    gen = jnp.asarray(generator_matrix(t, r), jnp.float32)
    got = ops.cdc_encode(w, gen)
    want = ref.cdc_encode_ref(w, gen)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("t", [2, 4, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cdc_decode_all_single_erasures(t, dtype):
    k1, k2 = jax.random.split(KEY)
    y = _rand(k1, (t, 128, 256), dtype)
    parity = y.astype(jnp.float32).sum(0).astype(dtype)
    for dead in [None, 0, t // 2, t - 1]:
        valid = jnp.ones(t, bool)
        if dead is not None:
            valid = valid.at[dead].set(False)
        got = ops.cdc_decode(y, parity, valid)
        want = ref.cdc_decode_ref(y, parity, valid)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-5, atol=1e-5)
        # and the decode is actually a recovery:
        tol = 1e-4 if dtype == jnp.float32 else 0.15
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("rows,d", [(256, 512), (512, 1024), (128, 768)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(rows, d, dtype):
    k1, k2 = jax.random.split(KEY)
    x = _rand(k1, (rows, d), dtype)
    g = _rand(k2, (d,), jnp.float32) * 0.1 + 1.0
    got = ops.rmsnorm(x, g)
    want = ref.rmsnorm_ref(x, g)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@settings(max_examples=10, deadline=None)
@given(mi=st.integers(1, 4), ki=st.integers(1, 4), ni=st.integers(1, 4))
def test_property_matmul_multiple_of_blocks(mi, ki, ni):
    m, k, n = 128 * mi, 128 * ki, 128 * ni
    k1, k2 = jax.random.split(jax.random.PRNGKey(m + k + n))
    x, w = _rand(k1, (m, k), jnp.float32), _rand(k2, (k, n), jnp.float32)
    np.testing.assert_allclose(ops.matmul(x, w), ref.matmul_ref(x, w),
                               rtol=1e-4, atol=1e-4)


def test_kernel_matches_core_decode():
    """The Pallas decode and the core library's r=1 decode agree."""
    from repro.core import CodeSpec, decode_outputs
    t = 8
    y = _rand(KEY, (t, 128, 256), jnp.float32)
    spec = CodeSpec(t, 1)
    parity = y.sum(0)
    valid = jnp.ones(t, bool).at[3].set(False)
    got = ops.cdc_decode(jnp.where(valid[:, None, None], y, 0), parity, valid)
    want = decode_outputs(y, parity[None], valid, spec)
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-4, atol=1e-4)
