"""launch/dryrun.py end-to-end: lower+compile every smoke cell on 8 fake
host devices (subprocess — XLA locks the device count at first jax init)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.multidev

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dryrun_smoke_compiles_all_cells(tmp_path):
    out_json = tmp_path / "dryrun.json"
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke", "--coded",
         "--mesh", "both", "--out", str(out_json)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])

    cells = json.loads(out_json.read_text())
    assert len(cells) >= 4  # train+decode smoke shapes x single+multi pod
    assert all(rec["status"] == "ok" for rec in cells.values()), cells
    # the pre-set 8-device XLA_FLAGS was respected (not clobbered to 512):
    # cells compiled on the (2,4) and (pod,2,2) test meshes
    meshes = {rec["mesh"] for rec in cells.values()}
    assert meshes == {"2x4", "pod2x2x2"}
    # coded cells lower the recovery math: parity GEMMs are in the step
    assert all(rec["coded"] for rec in cells.values())
