"""Hypothesis property tests on system-level invariants."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fallback keeps the suite collecting everywhere
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs import get_arch, smoke_config
from repro.core import CodedDenseSpec, CodeSpec, coded_matmul, \
    make_parity_weights
from repro.models import TPCtx, build


@settings(max_examples=8, deadline=None)
@given(split=st.integers(1, 9))
def test_prefill_decode_split_invariance(split):
    """Invariant: for ANY split point, prefill(prompt[:k]) then decoding
    prompt[k:] token-by-token yields the same final logits as teacher
    forcing — the ring cache + position bookkeeping is consistent."""
    cfg = smoke_config(get_arch("h2o-danube-1.8b"))  # SWA ring cache
    m = build(cfg, TPCtx(moe_capacity=0))
    params = m.init(jax.random.PRNGKey(0))
    batch = m.dummy_batch(jax.random.PRNGKey(1), 2, 10)
    full = m.forward(params, batch, remat="none")  # [B, 10, V]

    state = m.init_decode(params, batch, 2, 32, jnp.float32)
    lg, state = m.decode(params, state,
                         batch["tokens"][:, :split])
    outs = [lg[:, -1]]
    for t in range(split, 10):
        lg, state = m.decode(params, state, batch["tokens"][:, t:t + 1])
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full[:, split - 1:]),
                               rtol=3e-3, atol=3e-3)


@settings(max_examples=15, deadline=None)
@given(t=st.sampled_from([2, 4, 8]), seed=st.integers(0, 100),
       scale=st.floats(0.01, 10.0))
def test_coded_matmul_linearity(t, seed, scale):
    """Invariant: coding commutes with scaling and addition of inputs
    (linearity is WHY offline encode works, paper §5.2)."""
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (3, 16))
    w = jax.random.normal(kw, (16, t * t * 4))
    spec = CodedDenseSpec(CodeSpec(t, 2))
    w_cdc = make_parity_weights(w, spec)
    valid = jnp.ones(t, bool).at[seed % t].set(False)
    y1 = coded_matmul(x, w, w_cdc, spec, valid)
    y2 = coded_matmul(scale * x, w, w_cdc, spec, valid)
    np.testing.assert_allclose(np.asarray(y2), scale * np.asarray(y1),
                               rtol=2e-3, atol=2e-3 * scale)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_checkpoint_roundtrip_random_pytrees(seed, tmp_path_factory):
    """Invariant: save/restore is the identity on arbitrary pytrees."""
    from repro.ckpt import restore, save
    rng = np.random.default_rng(seed)
    tree = {
        "a": jnp.asarray(rng.standard_normal((rng.integers(1, 8),
                                              rng.integers(1, 8)))),
        "n": {"b": jnp.asarray(rng.integers(0, 100, size=5), jnp.int32),
              "c": [jnp.asarray(rng.standard_normal(3), jnp.float32)
                    for _ in range(rng.integers(1, 3))]},
    }
    d = str(tmp_path_factory.mktemp("ck") / f"s{seed}")
    save(tree, d, seed)
    out = restore(jax.tree.map(jnp.zeros_like, tree), d, seed)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64))


@settings(max_examples=6, deadline=None)
@given(p_fail=st.floats(0.0, 0.4), seed=st.integers(0, 50))
def test_erasure_sampler_respects_budget(p_fail, seed):
    """Invariant: the failure sampler never exceeds the decodable budget."""
    from repro.core.failure import sample_erasures
    rng = np.random.default_rng(seed)
    for T, r in [(4, 1), (8, 2), (16, 4)]:
        valid = sample_erasures(rng, T, p_fail, max_erasures=r)
        assert (~valid).sum() <= r
