"""Perf observability (``repro.obs.perf`` + ``repro.obs.history``).

Tier-1 properties: roofline attribution of the live executor rounds gives
a CPU-smoke ``roofline_utilization`` in (0, 1] (CPU is far slower than
the TPU-modelled bound), the paper's Fig. 2 constant-cost claim holds as
a runtime metric (``parity_device_equiv`` flat across T at fixed r while
``coded_overhead_frac`` falls), the fused full-Pallas round reports
non-zero FLOPs within 5% of the reference round at r=1 (the Pallas
custom-call cost registry agrees with counted HLO dots), synthetic
TPU-style custom-call HLO is costed through the registry by
longest-name containment, the benchmark history appends/loads/compares
round-trip with a regression gate that fires on a synthetic 30% slowdown
and stays quiet within tolerance, perf counter events validate as a
Perfetto counter track, disabled tracing emits nothing, and the live
``MetricsServer`` answers ``/healthz`` and exposes ``repro_perf_*``
gauges.
"""
import json
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs import get_arch, smoke_config
from repro.models import TPCtx, build
from repro.obs import (FlightRecorder, MetricsServer, chrome_trace,
                       prometheus_text, validate_chrome_trace)
from repro.obs.history import (append_snapshot, check_history, compare,
                               load_history, make_snapshot)
from repro.obs.perf import PerfMonitor, attribute_round_costs
from repro.roofline.hlo_cost import analyze_hlo
from repro.runtime import (ContinuousBatchingScheduler, RuntimeConfig,
                           run_arrivals)
from repro.runtime.executor import SlotPoolExecutor
from repro.serve import ModelStepper

GEN = 4
PROMPT_LEN = 8


def _stepper(tp=4, code_r=1, arch="granite-3-8b"):
    cfg = smoke_config(get_arch(arch))
    model = build(cfg, TPCtx(tp=tp, mode="coded", code_r=code_r,
                             moe_capacity=0))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ModelStepper(model, params, max_len=32)


def _workload(cfg, n=3, span_ms=150.0):
    rng = np.random.default_rng(7)
    gap = span_ms / max(n, 1)
    return [(i * gap, rng.integers(0, cfg.vocab, PROMPT_LEN), GEN)
            for i in range(n)]


def _costs(tp, code_r, use_fused=False):
    _, stepper = _stepper(tp=tp, code_r=code_r)
    ex = SlotPoolExecutor(stepper, 2, use_fused=use_fused)
    return attribute_round_costs(ex.vstep, ex.state, ex.last_toks)


# ------------------------------------------------------- attribution ----

@pytest.fixture(scope="module")
def perf_run():
    """One CPU smoke serve with perf accounting + tracing on."""
    cfg, stepper = _stepper()
    tracer = FlightRecorder()
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=2, perf=True), tracer=tracer)
    run_arrivals(sched, _workload(cfg))
    return sched, tracer


def test_utilization_in_unit_interval_on_cpu(perf_run):
    sched, _ = perf_run
    perf = sched.executor.perf
    assert perf.n_observed > 0
    s = perf.summary()
    # the roofline bound models the TPU HW target; a CPU round is orders
    # of magnitude slower, so utilization must land strictly inside (0, 1]
    assert 0.0 < s["roofline_utilization"] <= 1.0
    assert s["achieved_flops_per_s"] > 0
    assert s["hbm_gbs"] > 0
    assert s["model_flops"] > 0
    assert s["parity_flops"] >= 0
    # merged into the runtime metrics for the Prometheus gauges
    assert sched.metrics.perf["roofline_utilization"] == \
        s["roofline_utilization"]
    assert sched.metrics.perf["n_rounds_observed"] == perf.n_observed


def test_parity_device_equiv_flat_across_T():
    """Fig. 2 as a runtime metric: at fixed r the parity work equals ~r
    device-equivalents of one shard's useful work, independent of T —
    while parity/total (coded_overhead_frac) falls as T grows."""
    c2 = _costs(tp=2, code_r=1)["reference"]
    c4 = _costs(tp=4, code_r=1)["reference"]
    assert c2.T == 2 and c4.T == 4 and c2.r == c4.r == 1
    assert c2.parity_device_equiv > 0 and c4.parity_device_equiv > 0
    rel = abs(c4.parity_device_equiv - c2.parity_device_equiv) \
        / c2.parity_device_equiv
    assert rel < 0.10, (c2.parity_device_equiv, c4.parity_device_equiv)
    # the naive parity/total ratio is NOT flat: it shrinks with T
    assert c4.coded_overhead_frac < c2.coded_overhead_frac


def test_fused_round_flops_within_5pct_of_reference():
    """The Pallas custom-call cost registry must agree with counted HLO
    dots: at r=1 the fused round (sum-parity head, T+1 GEMMs) does the
    same work as the reference round (T+r GEMMs)."""
    costs = _costs(tp=4, code_r=1, use_fused=True)
    assert set(costs) == {"reference", "fused"}
    ref, fused = costs["reference"], costs["fused"]
    assert fused.flops > 0, "fused round reported zero FLOPs"
    assert abs(fused.flops / ref.flops - 1.0) < 0.05, (fused.flops,
                                                       ref.flops)
    # both variants attribute against the same plain-model useful FLOPs
    assert fused.useful_flops == ref.useful_flops > 0


# --------------------------------------------- custom-call cost model ----

_SYNTH_HLO = """\
HloModule synth

ENTRY %main (p0: f32[8,64], p1: f32[4,64,16], p2: f32[1,64,16]) -> f32[8,4,16] {
  %p0 = f32[8,64]{1,0} parameter(0)
  %p1 = f32[4,64,16]{2,1,0} parameter(1)
  %p2 = f32[1,64,16]{2,1,0} parameter(2)
  %unk = f32[8,16]{1,0} custom-call(%p0), custom_call_target="tpu_custom_call", metadata={op_name="jit(round)/jit(mystery_kernel)/pallas_call"}
  ROOT %cc = f32[8,4,16]{2,1,0} custom-call(%p0, %p1, %p2), custom_call_target="tpu_custom_call", metadata={op_name="jit(round)/jit(cdc_coded_matmul_pallas)/pallas_call"}
}
"""


def test_synthetic_custom_call_is_costed_via_registry():
    """TPU-style opaque custom-calls: the registry models the coded-GEMM
    kernel ((T+r) shard GEMMs) and counts the unknown kernel as uncosted
    instead of silently reporting ~0 FLOPs."""
    res = analyze_hlo(_SYNTH_HLO)
    # out [rows=8, T=4, m_l=16], w_shards [4,64,16] -> k=64, parity [1,..]
    assert res["flops"] == 2.0 * 8 * 64 * 16 * (4 + 1)
    assert res["custom_calls_costed"] == 1
    assert res["custom_calls_uncosted"] == 1


def test_registry_longest_name_containment():
    """``matmul_pallas`` is a substring of ``cdc_coded_matmul_pallas``:
    the longer (exact) kernel name must win the match."""
    res = analyze_hlo(_SYNTH_HLO)
    # the plain-matmul model on a rank-3 output would return 0.0 (shape
    # guard) — the (T+r)-GEMM result proves the coded model was chosen
    assert res["flops"] > 0


def test_interpret_and_registry_costs_agree():
    """CPU interpret mode inlines the kernels into real HLO dots; forcing
    the fused path there must therefore report comparable FLOPs to what
    the registry models for the native custom-call (same 5% band the
    fused-vs-reference check relies on)."""
    costs = _costs(tp=2, code_r=1, use_fused=True)
    assert abs(costs["fused"].flops / costs["reference"].flops - 1) < 0.05


# ------------------------------------------------------------ history ----

def test_history_append_load_roundtrip(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    rec = append_snapshot(path, "serve_throughput", "granite-3-8b",
                          {"rounds_per_s": 100.0, "model_flops": 1e6,
                           "skipme": None})
    assert rec["schema"] == 1 and rec["git_sha"]
    assert "skipme" not in rec["metrics"]
    append_snapshot(path, "serve_throughput", "granite-3-8b",
                    {"rounds_per_s": 101.0, "model_flops": 1e6})
    loaded = load_history(path)
    assert [r["metrics"]["rounds_per_s"] for r in loaded] == [100.0, 101.0]
    # unparsable lines and newer-schema records are skipped, not fatal
    with open(path, "a") as f:
        f.write("not json\n")
        f.write(json.dumps({"schema": 99, "metrics": {}}) + "\n")
    assert len(load_history(path)) == 2


def test_compare_quiet_within_tolerance_and_fires_beyond():
    base = [make_snapshot("b", "a", {"rounds_per_s": 100.0,
                                     "ttft_p99_ms": 50.0,
                                     "model_flops": 1e6})
            for _ in range(5)]
    ok = make_snapshot("b", "a", {"rounds_per_s": 90.0,   # -10% < 25% tol
                                  "ttft_p99_ms": 55.0,
                                  "model_flops": 1e6})
    assert compare(ok, base) == []
    bad = make_snapshot("b", "a", {"rounds_per_s": 60.0,  # -40% regression
                                   "ttft_p99_ms": 120.0,  # +140% regression
                                   "model_flops": 2e6})   # drifted
    names = {r["metric"] for r in compare(bad, base)}
    assert names == {"rounds_per_s", "ttft_p99_ms", "model_flops"}


def test_regression_gate_fires_on_synthetic_slowdown(tmp_path):
    path = str(tmp_path / "hist.jsonl")
    for v in (100.0, 102.0, 98.0):
        append_snapshot(path, "serve_throughput", "granite-3-8b",
                        {"rounds_per_s": v, "ttft_p99_ms": 50.0})
    # within tolerance: the last record vs its predecessors is quiet
    results = check_history(path)
    assert len(results) == 1 and results[0]["regressions"] == []
    # a 30% synthetic slowdown MUST trip the 25% rounds_per_s tolerance
    fired = check_history(path, inject_slowdown=0.30)
    assert any(r["regressions"] for r in fired)
    metrics = {reg["metric"] for r in fired for reg in r["regressions"]}
    assert "rounds_per_s" in metrics
    # CLI exit codes mirror that (what the CI gate asserts on)
    from repro.obs.history import main as history_main
    assert history_main(["check", "--path", path]) == 0
    assert history_main(["check", "--path", path,
                         "--inject-slowdown", "0.30"]) == 1


# ----------------------------------------------------- trace + gauges ----

def test_perf_counter_track_validates(perf_run):
    _, tracer = perf_run
    kinds = {e.kind for e in tracer.events()}
    assert "perf.attribution" in kinds and "perf.counter" in kinds
    trace = chrome_trace(tracer)
    stats = validate_chrome_trace(trace, require_perf_counters=True)
    assert stats["n_perf_counters"] > 0
    # counter events carry numeric-only args (Perfetto charts them)
    counters = [e for e in trace["traceEvents"] if e.get("ph") == "C"]
    assert counters
    for ev in counters:
        assert ev["args"]
        assert all(isinstance(v, (int, float)) and not isinstance(v, bool)
                   for v in ev["args"].values())


def test_validate_requires_perf_counters_when_asked():
    rec = FlightRecorder()
    rec.emit("round.dispatch", track="rounds", round=0, n_active=1, dead=[])
    with pytest.raises(ValueError, match="perf"):
        validate_chrome_trace(chrome_trace(rec), require_perf_counters=True)


def test_perf_without_tracer_emits_nothing():
    """Perf accounting with tracing disabled: gauges still update, but the
    NULL recorder records zero events (and its emit is a no-op branch)."""
    cfg, stepper = _stepper()
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=2, perf=True))
    run_arrivals(sched, _workload(cfg, n=2))
    assert sched.executor.perf.n_observed > 0
    assert sched.metrics.perf["roofline_utilization"] > 0
    assert not sched.tracer.enabled
    assert list(sched.tracer.events()) == []


def test_metrics_server_healthz_and_perf_gauges(perf_run):
    sched, tracer = perf_run
    text = prometheus_text(sched.metrics, sched.shardlog,
                           now_ms=sched.clock.now())
    assert "repro_perf_roofline_utilization" in text
    assert "repro_perf_coded_overhead_frac" in text
    server = MetricsServer(sched.metrics, sched.shardlog, tracer,
                           sched.clock, port=0).start()
    try:
        base = f"http://127.0.0.1:{server.port}"
        with urllib.request.urlopen(f"{base}/healthz", timeout=10) as r:
            assert r.status == 200
            assert r.read() == b"ok\n"
        with urllib.request.urlopen(f"{base}/metrics", timeout=10) as r:
            body = r.read().decode()
        assert "repro_perf_achieved_flops_per_s" in body
    finally:
        server.stop()
