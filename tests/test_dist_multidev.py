"""Multi-device tests (subprocess: 8 fake host devices).

XLA locks the device count at first jax init, so these run in fresh
interpreter processes with XLA_FLAGS set. Validates that GSPMD sharding of
the coded model is semantics-preserving: the sharded coded forward equals
the single-device forward, with and without erasures.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidev

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_coded_forward_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_arch, smoke_config
        from repro.models import TPCtx, build
        from repro.dist.sharding import param_shardings, batch_spec

        assert len(jax.devices()) == 8
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = smoke_config(get_arch("granite-3-8b"))

        # single-device reference (same logical T=4 coded math)
        ctx0 = TPCtx(tp=4, mode="coded", code_r=2, moe_capacity=0)
        m0 = build(cfg, ctx0)
        params = m0.init(jax.random.PRNGKey(0))
        batch = m0.dummy_batch(jax.random.PRNGKey(1), 4, 8)
        valid = jnp.ones(4, bool)
        ref = m0.forward(params, batch, valid)

        # sharded on the mesh
        ctx = TPCtx(tp=4, mode="coded", code_r=2, mesh=mesh, moe_capacity=0)
        m = build(cfg, ctx)
        ps = param_shardings(params, mesh)
        params_sh = jax.device_put(params, ps)
        batch_sh = jax.device_put(
            batch, {"tokens": NamedSharding(mesh, batch_spec(mesh))})
        fwd = jax.jit(lambda p, b, v: m.forward(p, b, v))
        got = fwd(params_sh, batch_sh, valid)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

        # erasure under sharding: still equals fault-free reference
        dead = valid.at[1].set(False)
        got_dead = fwd(params_sh, batch_sh, dead)
        np.testing.assert_allclose(np.asarray(got_dead), np.asarray(ref),
                                   rtol=5e-3, atol=5e-3)
        print("OK")
    """)
    assert "OK" in out


def test_plain_tp_sharded_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_arch, smoke_config
        from repro.models import TPCtx, build
        from repro.dist.sharding import param_shardings, batch_spec

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = smoke_config(get_arch("qwen2-moe-a2.7b"))
        ctx0 = TPCtx(tp=4, moe_capacity=0)
        m0 = build(cfg, ctx0)
        params = m0.init(jax.random.PRNGKey(0))
        batch = m0.dummy_batch(jax.random.PRNGKey(1), 4, 8)
        ref = m0.forward(params, batch)

        ctx = TPCtx(tp=4, mesh=mesh, moe_capacity=0)
        m = build(cfg, ctx)
        params_sh = jax.device_put(params, param_shardings(params, mesh))
        batch_sh = jax.device_put(
            batch, {"tokens": NamedSharding(mesh, batch_spec(mesh))})
        got = jax.jit(lambda p, b: m.forward(p, b))(params_sh, batch_sh)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)
        print("OK")
    """)
    assert "OK" in out


def test_multipod_mesh_and_elastic_restore():
    """(pod,data,model) mesh accepts the shardings; a checkpoint saved from
    the 8-device mesh restores onto a 1-device process (elastic re-mesh)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from repro.configs import get_arch, smoke_config
        from repro.models import TPCtx, build
        from repro.dist.sharding import param_shardings
        from repro.ckpt import save

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = smoke_config(get_arch("h2o-danube-1.8b"))
        ctx = TPCtx(tp=2, mesh=mesh)
        m = build(cfg, ctx)
        params = m.init(jax.random.PRNGKey(0))
        params_sh = jax.device_put(params, param_shardings(params, mesh))
        d = tempfile.mkdtemp()
        save(params_sh, d, 3)
        print("SAVED", d)
    """)
    assert "SAVED" in out
    ckpt_dir = out.strip().split()[-1]
    out2 = _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch, smoke_config
        from repro.models import TPCtx, build
        from repro.ckpt import restore

        cfg = smoke_config(get_arch("h2o-danube-1.8b"))
        m = build(cfg, TPCtx(tp=2))
        tmpl = m.init(jax.random.PRNGKey(42))
        out = restore(tmpl, {ckpt_dir!r}, 3)
        # restored values differ from the fresh init => real load happened
        a = np.asarray(jax.tree.leaves(out)[0], np.float32)
        b = np.asarray(jax.tree.leaves(tmpl)[0], np.float32)
        assert not np.allclose(a, b)
        print("OK")
    """)
    assert "OK" in out2


def test_shardmap_coded_matmul_explicit_placement():
    """Erasure sweep over the shard_map coded GEMM (explicit per-device
    placement): EVERY single dead shard index for T=4, r=2 recovers and
    matches both the plain GEMM and the GSPMD/logical path. The masks are
    driven through the shard-health controller, which also maps each
    erasure onto the real mesh devices holding that shard."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import CodedDenseSpec, CodeSpec, coded_matmul, \\
            make_parity_weights
        from repro.dist.collectives import coded_matmul_shardmap
        from repro.runtime.health import ShardHealthController, erasure, \\
            recovery

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        T = 4
        spec = CodedDenseSpec(CodeSpec(T, 2))
        kx, kw = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(kx, (8, 64))
        w = jax.random.normal(kw, (64, T * T * 8)) / 8.0
        w_cdc = make_parity_weights(w, spec)
        ref = x @ w
        ctrl = ShardHealthController(T, spec.max_device_failures)
        for dead in (None,) + tuple(range(T)):
            if dead is not None:
                ctrl.apply(erasure(0.0, dead))
            valid = jnp.asarray(ctrl.mask)
            # logical shard <-> physical device placement is real: the
            # controller names the mesh devices the erasure hit
            dmask = ctrl.device_mask(mesh)
            assert dmask.shape == mesh.devices.shape
            assert len(ctrl.dead_devices(mesh)) == \\
                (0 if dead is None else 2)  # one per data replica
            got = coded_matmul_shardmap(x, w, w_cdc, spec, valid, mesh=mesh)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       rtol=2e-3, atol=2e-3)
            logical = coded_matmul(x, w, w_cdc, spec, valid)
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(logical),
                                       rtol=1e-4, atol=1e-4)
            if dead is not None:
                ctrl.apply(recovery(1.0, dead))
        print("OK")
    """)
    assert "OK" in out
