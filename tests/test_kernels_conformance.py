"""Differential conformance for the fused in-body coded kernels.

The PR-7 acceptance pin: the fused Pallas coded GEMM + Eq. 12
decode-and-merge (``kernels.cdc_matmul`` via ``kernels.ops``) must agree
with THREE independent answers —

  fused kernel  ≡  ref.py oracle  ≡  core.coded_matmul  ≡  plain x @ w

— over T∈{2,4} × r∈{1,2}, both parity layouts, EVERY in-budget erasure
mask (including the 2-erasure dedicated masks that must take the exact
reference fallback), odd/non-tile-multiple shapes, and f32/bf16 with an
explicit per-dtype tolerance contract. Plus the structural guarantee the
kernels exist for: the fused path's jaxpr holds exactly ONE pallas_call
and ZERO outside-kernel dot_generals — per-shard GEMM outputs never
round-trip HBM.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.coded_layer import (CodedDenseSpec, coded_matmul,
                                    decode_and_merge, make_parity_weights)
from repro.core.coding import CodeSpec
from repro.kernels import ops, ref
from repro.models.common import rmsnorm

# ---------------------------------------------------------------------------
# Tolerance contract. The kernel accumulates every GEMM in f32; the
# reference path accumulates in the input dtype (bf16 stays bf16), so the
# fused-vs-reference delta is bounded by the REFERENCE's accumulation
# error, not the kernel's. The oracle mirrors the kernel's f32 math
# exactly and is bit-identical in interpret mode; the looser oracle bound
# only allows for native-TPU rounding.
TOL = {
    "float32": dict(rtol=1e-4, atol=1e-4),    # vs reference / plain
    "bfloat16": dict(rtol=6e-2, atol=6e-2),
}
ORACLE_TOL = {
    "float32": dict(rtol=1e-5, atol=1e-5),    # vs ref.py oracle
    "bfloat16": dict(rtol=2e-2, atol=2e-2),
}

CASES = [(T, r, layout)
         for T in (2, 4) for r in (1, 2)
         for layout in ("folded", "dedicated")]
DTYPES = (jnp.float32, jnp.bfloat16)


def inbudget_masks(T: int, budget: int) -> list[tuple[bool, ...]]:
    """The full mask plus EVERY erasure subset within the code budget."""
    masks = [tuple([True] * T)]
    for f in range(1, budget + 1):
        for dead in itertools.combinations(range(T), f):
            m = [True] * T
            for d in dead:
                m[d] = False
            masks.append(tuple(m))
    return masks


def make_case(T, r, layout, dtype, *, rows=8, k=64, m=None, seed=0):
    spec = CodedDenseSpec(CodeSpec(T, r), layout=layout)
    if m is None:
        # folded parity slices need m_l % T == 0; dedicated takes odd m_l
        m = T * T * 2 if layout == "folded" else 28
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (rows, k)).astype(dtype)
    w = (jax.random.normal(kw, (k, m)) / np.sqrt(k)).astype(dtype)
    return spec, x, w, make_parity_weights(w, spec)


def _allclose(a, b, tol, msg):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               err_msg=msg, **tol)


# ------------------------------------------------- the core differential ----

@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: np.dtype(d).name)
@pytest.mark.parametrize("T,r,layout", CASES)
def test_fused_matches_oracle_reference_and_plain(T, r, layout, dtype):
    """fused ≡ oracle ≡ core.coded_matmul ≡ x@w under EVERY in-budget
    mask (single-erasure masks take the kernel; multi-erasure masks must
    take the bitwise-exact reference fallback)."""
    spec, x, w, wc = make_case(T, r, layout, dtype)
    dname = np.dtype(dtype).name
    plain = x.astype(jnp.float32) @ w.astype(jnp.float32)
    for mask in inbudget_masks(T, spec.max_device_failures):
        v = jnp.asarray(mask)
        dead = T - sum(mask)
        reference = coded_matmul(x, w, wc, spec, v)
        fused = ops.fused_coded_matmul(x, w, wc, spec, v)
        assert fused.dtype == x.dtype and fused.shape == reference.shape
        if dead > 1:
            # beyond the Eq. 12 regime: the EXACT reference path, bitwise
            np.testing.assert_array_equal(
                np.asarray(fused), np.asarray(reference),
                err_msg=f"{layout} T={T} r={r} mask={mask}: multi-erasure "
                        f"fallback must be the reference path verbatim")
            continue
        oracle = ops.fused_coded_matmul(x, w, wc, spec, v, use_pallas=False)
        ctx = f"{layout} T={T} r={r} {dname} mask={mask}"
        _allclose(fused, oracle, ORACLE_TOL[dname], f"{ctx}: vs oracle")
        _allclose(fused, reference, TOL[dname], f"{ctx}: vs reference")
        _allclose(fused, plain, TOL[dname], f"{ctx}: vs plain x@w")


@pytest.mark.parametrize("T,r,layout", CASES)
def test_odd_shapes_and_block_padding(T, r, layout):
    """Non-tile-multiple rows/k/m_l and block sizes that do NOT divide
    the problem: the wrapper's pad-and-slice must be invisible."""
    m = T * T * 3 if layout == "folded" else T * 7      # odd m_l (dedicated)
    spec, x, w, wc = make_case(T, r, layout, jnp.float32,
                               rows=5, k=33, m=m, seed=1)
    for mask in inbudget_masks(T, min(spec.max_device_failures, 1)):
        v = jnp.asarray(mask)
        reference = coded_matmul(x, w, wc, spec, v)
        for bm, bn in ((3, 5), (128, 128), (2, 1)):
            fused = ops.fused_coded_matmul(x, w, wc, spec, v, bm=bm, bn=bn)
            _allclose(fused, reference, TOL["float32"],
                      f"{layout} T={T} r={r} mask={mask} bm={bm} bn={bn}")


@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: np.dtype(d).name)
@pytest.mark.parametrize("T,r,layout", CASES)
def test_decode_merge_matches_reference(T, r, layout, dtype):
    """The decode-and-merge tail (already-computed shard outputs, e.g.
    gathered by dist.collectives) — fused ≡ core.decode_and_merge under
    every in-budget mask, middle batch/seq dims included."""
    spec = CodedDenseSpec(CodeSpec(T, r), layout=layout)
    m_l = 2 * T if layout == "folded" else 7
    key = jax.random.PRNGKey(2)
    ky, kp = jax.random.split(key)
    pshape = ((T, 2, 3, r * (m_l // T)) if layout == "folded"
              else (r, 2, 3, m_l))
    ys = jax.random.normal(ky, (T, 2, 3, m_l)).astype(dtype)
    parity = jax.random.normal(kp, pshape).astype(dtype)
    dname = np.dtype(dtype).name
    for mask in inbudget_masks(T, spec.max_device_failures):
        v = jnp.asarray(mask)
        reference = decode_and_merge(ys, parity, spec, v)
        fused = ops.fused_decode_merge(ys, parity, spec, v)
        routed = decode_and_merge(ys, parity, spec, v, use_fused=True)
        if T - sum(mask) > 1:
            np.testing.assert_array_equal(np.asarray(fused),
                                          np.asarray(reference))
            continue
        ctx = f"{layout} T={T} r={r} {dname} mask={mask}"
        _allclose(fused, reference, TOL[dname], ctx)
        np.testing.assert_array_equal(
            np.asarray(routed), np.asarray(fused),
            err_msg=f"{ctx}: decode_and_merge(use_fused=True) must route "
                    f"to the fused op")


# ----------------------------------------------- property-based sweep ----

@settings(deadline=None, max_examples=12)
@given(data=st.data())
def test_fused_matches_reference_property(data):
    """Random geometry × values × in-budget mask: fused ≡ reference."""
    T = data.draw(st.sampled_from([2, 4]))
    r = data.draw(st.sampled_from([1, 2]))
    layout = data.draw(st.sampled_from(["folded", "dedicated"]))
    rows = data.draw(st.integers(1, 9))
    k = data.draw(st.integers(3, 48))
    m_l = data.draw(st.integers(1, 6)) * T  # folded-safe
    seed = data.draw(st.integers(0, 2 ** 16))
    spec, x, w, wc = make_case(T, r, layout, jnp.float32,
                               rows=rows, k=k, m=T * m_l, seed=seed)
    masks = inbudget_masks(T, min(spec.max_device_failures, 1))
    mask = masks[data.draw(st.integers(0, len(masks) - 1))]
    v = jnp.asarray(mask)
    fused = ops.fused_coded_matmul(x, w, wc, spec, v)
    _allclose(fused, coded_matmul(x, w, wc, spec, v), TOL["float32"],
              f"{layout} T={T} r={r} rows={rows} k={k} m_l={m_l} "
              f"mask={mask} seed={seed}")


# --------------------------------------------- rmsnorm fold (stretch) ----

@pytest.mark.parametrize("layout", ("folded", "dedicated"))
def test_rmsnorm_fold_matches_norm_then_matmul(layout):
    """gamma-folding: fused(norm+GEMM+decode+merge) ≡ rmsnorm then the
    reference coded matmul, fault-free and under one erasure."""
    T, r = 4, 2
    spec, x, w, wc = make_case(T, r, layout, jnp.float32, seed=3)
    g = jax.random.normal(jax.random.PRNGKey(4), (x.shape[-1],)) * 0.1 + 1.0
    for mask in [(True,) * T, (True, False, True, True)]:
        v = jnp.asarray(mask)
        xn = rmsnorm({"g": g}, x)                   # models' eps=1e-5
        reference = coded_matmul(xn, w, wc, spec, v)
        fused = ops.fused_coded_matmul(x, w, wc, spec, v, gamma=g, eps=1e-5)
        _allclose(fused, reference, TOL["float32"],
                  f"{layout} mask={mask}: rmsnorm fold")


# ------------------------------------------- erasure-limit guards ----

def test_fused_head_argmax_rejects_multi_erasure():
    """Satellite: the sum-parity fused head recovers <=1 shard; a
    concrete 2-dead mask must raise loudly, never decode garbage."""
    x = jnp.ones((2, 8))
    w_shards = jnp.ones((4, 8, 4))
    with pytest.raises(ValueError, match="at most 1 erased"):
        ops.fused_head_argmax(x, w_shards, w_shards.sum(0),
                              jnp.asarray([False, True, False, True]),
                              vocab=15)


def test_cdc_decode_rejects_multi_erasure():
    with pytest.raises(ValueError, match="at most 1 erased"):
        ops.cdc_decode(jnp.ones((4, 8, 8)), jnp.ones((8, 8)),
                       jnp.asarray([False, False, True, True]))


def test_multi_erasure_matmul_falls_back_not_raises():
    """The in-body op DOES have an exact fallback (full MDS reference):
    an in-budget 2-erasure dedicated mask returns the reference answer."""
    spec, x, w, wc = make_case(4, 2, "dedicated", jnp.float32, seed=5)
    v = jnp.asarray([True, False, False, True])
    out = ops.fused_coded_matmul(x, w, wc, spec, v)
    _allclose(out, x.astype(jnp.float32) @ w.astype(jnp.float32),
              TOL["float32"], "2-erasure recovery through the fallback")


# -------------------------------------------- policy + structure pins ----

def test_auto_policy_is_reference_off_tpu():
    """use_fused='auto' must resolve to the plain-jnp reference path off
    TPU (bitwise) — interpret mode is opt-in via use_fused=True."""
    spec, x, w, wc = make_case(4, 2, "folded", jnp.float32, seed=6)
    v = jnp.asarray([True, False, True, True])
    auto = coded_matmul(x, w, wc, spec, v, use_fused="auto")
    reference = coded_matmul(x, w, wc, spec, v)
    if jax.default_backend() != "tpu":
        np.testing.assert_array_equal(np.asarray(auto),
                                      np.asarray(reference))
    else:
        _allclose(auto, reference, TOL["float32"], "auto on TPU")


def _count_primitives(closed_jaxpr):
    """(n_pallas_call, n_dot_general_outside_kernels) over the whole
    jaxpr tree — dot_generals INSIDE a pallas_call body are the in-VMEM
    kernel math and don't count as an HBM round-trip."""
    counts = {"pallas_call": 0, "dot_general": 0}

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in counts:
                counts[name] += 1
            if name == "pallas_call":
                continue                      # kernel-internal math
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        walk(sub.jaxpr)
                    elif isinstance(sub, jax.core.Jaxpr):
                        walk(sub)

    walk(closed_jaxpr.jaxpr)
    return counts["pallas_call"], counts["dot_general"]


def test_fused_path_has_no_pershard_hbm_roundtrip():
    """Structural acceptance pin: the fused coded matmul lowers to
    exactly ONE pallas_call with ZERO GEMMs outside it — shard outputs
    and parity outputs live only in kernel VMEM, the only HBM write is
    the merged activation."""
    spec, x, w, wc = make_case(4, 2, "folded", jnp.float32, seed=7)
    v = jnp.asarray([True, False, True, True])
    jaxpr = jax.make_jaxpr(
        lambda xx: ops.fused_coded_matmul(xx, w, wc, spec, v))(x)
    n_pallas, n_dots = _count_primitives(jaxpr)
    assert n_pallas == 1, f"expected one fused kernel, got {n_pallas}"
    assert n_dots == 0, (f"{n_dots} dot_general(s) outside the kernel — "
                         f"per-shard outputs are round-tripping HBM")
    # the reference path, for contrast, runs its GEMMs as plain XLA dots
    jaxpr_ref = jax.make_jaxpr(
        lambda xx: coded_matmul(xx, w, wc, spec, v))(x)
    _, ref_dots = _count_primitives(jaxpr_ref)
    assert ref_dots >= 1


def test_merge_is_free_reshape():
    """The kernel writes [rows, T, m_l] in merge order: flattening the
    last two axes IS the merged activation (column t*m_l + c)."""
    spec, x, w, wc = make_case(4, 2, "folded", jnp.float32, seed=8)
    v = jnp.ones(4, bool)
    fused = ops.fused_coded_matmul(x, w, wc, spec, v)
    T = 4
    m = w.shape[1]
    m_l = m // T
    per_shard = np.asarray(fused).reshape(x.shape[0], T, m_l)
    plain = np.asarray(x @ w).reshape(x.shape[0], T, m_l)
    np.testing.assert_allclose(per_shard, plain, rtol=1e-4, atol=1e-4)
