"""Per-architecture smoke tests (reduced configs, CPU, one fwd/train step).

For every assigned arch: (a) forward produces the right shapes with no NaNs,
(b) incremental decode with the KV cache/recurrent state matches the
teacher-forced forward pass, (c) model-level CDC: a dead TP shard leaves the
logits (numerically) unchanged, (d) a gradient step is finite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_arch, smoke_config
from repro.models import TPCtx, build

ARCHS = sorted(all_archs().keys())
KEY = jax.random.PRNGKey(0)


def _model(name, ctx=None):
    cfg = smoke_config(get_arch(name))
    # moe_capacity<=0: no token dropping, so teacher-forced forward and
    # incremental decode see identical expert routing (exactness mode).
    m = build(cfg, ctx or TPCtx(moe_capacity=0))
    params = m.init(jax.random.PRNGKey(1))
    batch = m.dummy_batch(jax.random.PRNGKey(2), 2, 12)
    return cfg, m, params, batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_no_nan(name):
    cfg, m, params, batch = _model(name)
    logits = m.forward(params, batch)
    assert logits.shape == (2, 12, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(name):
    """Incremental decode (ring KV cache / SSM state) == teacher forcing."""
    cfg, m, params, batch = _model(name)
    full = m.forward(params, batch, remat="none")  # [B, S, V]
    state = m.init_decode(params, batch, 2, 32, jnp.float32)
    outs = []
    for t in range(batch["tokens"].shape[1]):
        lg, state = m.decode(params, state, batch["tokens"][:, t:t + 1])
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ARCHS)
def test_cdc_model_level_recovery(name):
    """A dead TP shard (folded r=2) does not change model outputs."""
    ctx = TPCtx(tp=4, mode="coded", code_r=2, moe_capacity=0)
    cfg, m, params, batch = _model(name, ctx)
    ok = m.forward(params, batch, jnp.ones(4, bool))
    dead = m.forward(params, batch, jnp.ones(4, bool).at[2].set(False))
    np.testing.assert_allclose(np.asarray(dead), np.asarray(ok),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("name", ARCHS)
def test_grad_step_finite(name):
    cfg, m, params, batch = _model(name)
    tokens = batch["tokens"]

    def loss_fn(p):
        logits = m.forward(p, batch, remat="none")
        tgt = jnp.roll(tokens, -1, axis=1)
        ls = -jax.nn.log_softmax(logits)[
            jnp.arange(2)[:, None], jnp.arange(tokens.shape[1])[None], tgt]
        return ls.mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.isfinite(g).all(), grads))
    assert all(bool(x) for x in flat)


def test_exact_assigned_configs():
    """The full configs carry the exact published hyperparameters."""
    checks = {
        "granite-3-8b": dict(n_layers=40, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=12800, vocab=49155),
        "h2o-danube-1.8b": dict(n_layers=24, d_model=2560, n_heads=32,
                                n_kv_heads=8, d_ff=6912, vocab=32000,
                                attn_kind="swa"),
        "deepseek-67b": dict(n_layers=95, d_model=8192, n_heads=64,
                             n_kv_heads=8, d_ff=22016, vocab=102400),
        "h2o-danube-3-4b": dict(n_layers=24, d_model=3840, n_heads=32,
                                n_kv_heads=8, d_ff=10240, vocab=32000),
        "qwen2-moe-a2.7b": dict(n_layers=24, d_model=2048, n_heads=16,
                                n_kv_heads=16, vocab=151936, n_experts=60,
                                top_k=4, n_shared_experts=4,
                                d_ff_expert=1408),
        "qwen3-moe-235b-a22b": dict(n_layers=94, d_model=4096, n_heads=64,
                                    n_kv_heads=4, vocab=151936,
                                    n_experts=128, top_k=8),
        "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab=32001,
                           ssm_state=16),
        "whisper-medium": dict(n_layers=24, d_model=1024, n_heads=16,
                               n_kv_heads=16, d_ff=4096, vocab=51865,
                               encoder_layers=24),
        "xlstm-125m": dict(n_layers=12, d_model=768, n_heads=4,
                           n_kv_heads=4, d_ff=0, vocab=50304),
        "chameleon-34b": dict(n_layers=48, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=22016, vocab=65536),
    }
    assert set(checks) == set(ARCHS)
    for name, want in checks.items():
        cfg = get_arch(name)
        for k, v in want.items():
            assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)


def test_long_context_support_flags():
    """long_500k runnability matches DESIGN.md §6."""
    sub_q = {n: get_arch(n).sub_quadratic for n in ARCHS}
    assert sub_q == {
        "granite-3-8b": False, "deepseek-67b": False,
        "chameleon-34b": False, "whisper-medium": False,
        "qwen2-moe-a2.7b": False, "qwen3-moe-235b-a22b": False,
        "h2o-danube-1.8b": True, "h2o-danube-3-4b": True,
        "hymba-1.5b": True, "xlstm-125m": True,
    }


def test_mlstm_chunkwise_matches_sequential():
    """§Perf hillclimb 1 correctness: the chunkwise-parallel mLSTM equals
    the sequential recurrence (debug-forward discipline: the optimization
    must be bit-compatible up to fp32 reassociation)."""
    import jax
    import jax.numpy as jnp
    from repro.models.xlstm import _mlstm_chunkwise

    b, s, nh, dh = 2, 70, 3, 8  # s deliberately NOT a chunk multiple
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (b, s, nh, dh))
    k = jax.random.normal(ks[1], (b, s, nh, dh)) / dh ** 0.5
    v = jax.random.normal(ks[2], (b, s, nh, dh))
    i_raw = jax.random.normal(ks[3], (b, s, nh))
    f_log = -jax.nn.softplus(-jax.random.normal(ks[4], (b, s, nh)) - 1.0)
    c0 = jnp.zeros((b, nh, dh, dh))
    n0 = jnp.zeros((b, nh, dh))
    m0 = jnp.full((b, nh), -1e30)

    # sequential reference (the paper-faithful stabilized recurrence)
    def step(carry, inp):
        c, n, m = carry
        qi, ki, vi, ii, fi = inp
        m_new = jnp.maximum(fi + m, ii)
        i_g = jnp.exp(ii - m_new)[..., None]
        f_g = jnp.exp(fi + m - m_new)[..., None]
        c = f_g[..., None] * c + i_g[..., None] * \
            (vi[..., :, None] * ki[..., None, :])
        n = f_g * n + i_g * ki
        num = jnp.einsum("bhij,bhj->bhi", c, qi)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qi)), 1.0)
        return (c, n, m_new), num / den[..., None]

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, i_raw, f_log))
    (c_ref, n_ref, m_ref), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    h_ref = jnp.moveaxis(hs, 0, 1)

    h, (cT, nT, mT) = _mlstm_chunkwise(q, k, v, i_raw, f_log, c0, n0, m0,
                                       chunk=16)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cT), np.asarray(c_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(mT), np.asarray(m_ref),
                               rtol=1e-5, atol=1e-5)
