"""Pipeline-parallelism correctness (subprocess, 8 fake devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.multidev

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_gpipe_over_pod_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import pipeline_apply

        mesh = jax.make_mesh((4, 2), ("pod", "data"))
        L, D = 8, 32
        keys = jax.random.split(jax.random.PRNGKey(0), L)
        params = {"w": jnp.stack([
            jax.random.normal(k, (D, D)) / D ** 0.5 for k in keys]),
            "b": jnp.zeros((L, D))}

        def layer(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, D))

        # sequential reference
        h = x
        for i in range(L):
            h = layer(jax.tree.map(lambda a: a[i], params), h)

        got = pipeline_apply(layer, params, x, mesh=mesh, n_microbatches=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(h),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out
