from repro.roofline.analysis import (HW, collective_bytes, roofline_report,
                                     roofline_terms)
