"""Roofline terms from a compiled dry-run artifact (no real hardware).

Three terms, in seconds, for the per-device program (the SPMD-partitioned
HLO module IS the per-device program, so no /chips rescale is needed —
equivalent to the spec's total/(chips*peak) form):

  compute    = HLO_FLOPs / peak_FLOPs
  memory     = HLO_bytes_accessed / HBM_bw
  collective = wire_bytes / link_bw

wire_bytes comes from parsing the compiled HLO: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op contributes
ring-model bytes:
  all-gather:    out_bytes * (k-1)/k        (receives all but own slice)
  all-reduce:    2 * bytes * (k-1)/k        (reduce-scatter + all-gather)
  reduce-scatter: in_bytes * (k-1)/k  = out_bytes * (k-1)
  all-to-all:    bytes * (k-1)/k
  collective-permute: bytes
Hardware: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

HW = {
    "peak_flops": 197e12,   # bf16
    "hbm_bw": 819e9,        # bytes/s
    "link_bw": 50e9,        # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Ring-model wire bytes per collective kind, from compiled HLO text."""
    out: dict[str, float] = {"all-gather": 0.0, "all-reduce": 0.0,
                             "reduce-scatter": 0.0, "all-to-all": 0.0,
                             "collective-permute": 0.0}
    counts: dict[str, int] = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3)
        if "-done" in line.split("=")[1][:60]:
            continue  # the -start op already counted
        bytes_ = _shape_bytes(shape_str)
        gm = _GROUPS_RE.search(line)
        if gm:
            k = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            k = int(gi.group(2)) if gi else 2
        k = max(k, 2)
        if kind == "all-gather":
            wire = bytes_ * (k - 1) / k
        elif kind == "all-reduce":
            wire = 2 * bytes_ * (k - 1) / k
        elif kind == "reduce-scatter":
            wire = bytes_ * (k - 1)  # out is 1/k of input
        elif kind == "all-to-all":
            wire = bytes_ * (k - 1) / k
        else:  # collective-permute
            wire = bytes_
        out[kind] += wire
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def roofline_terms(cost: dict, coll: dict, hw: dict = HW) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    wire = float(coll.get("total", 0.0))
    t_c = flops / hw["peak_flops"]
    t_m = bytes_ / hw["hbm_bw"]
    t_x = wire / hw["link_bw"]
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    tot = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "bound_step_s": tot,
        "flops": flops, "bytes": bytes_, "wire_bytes": wire,
    }


def roofline_report(terms: dict, model_flops_per_device: float) -> dict:
    """Adds MODEL_FLOPS/HLO_FLOPs usefulness ratio and roofline fraction."""
    hlo_flops = terms["flops"]
    useful = model_flops_per_device / hlo_flops if hlo_flops else 0.0
    # fraction of the dominant-roofline bound that useful compute achieves
    t_useful = model_flops_per_device / HW["peak_flops"]
    frac = t_useful / terms["bound_step_s"] if terms["bound_step_s"] else 0.0
    return dict(terms, model_flops=model_flops_per_device,
                useful_ratio=useful, roofline_fraction=frac)
