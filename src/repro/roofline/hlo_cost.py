"""Trip-count-weighted cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
lax.scan over 95 layers or a 16-microbatch accumulation loop reports 1/95th
/ 1/16th of the real FLOPs (verified empirically; see EXPERIMENTS.md §Perf
lesson 0). Since the entire framework leans on scan-over-layers, the
roofline needs a loop-aware model. This module parses the compiled module:

  * per-computation local costs:
      flops: dot/convolution ops (2 * prod(out) * prod(contracted dims))
      bytes: sum of (operands + output) bytes of top-level kernels
             (fusion boundaries == HBM round trips; control ops skipped)
      wire:  ring-model collective bytes (same model as analysis.py)
  * call graph with multiplicities:
      while bodies x known_trip_count (backend_config annotation)
      fusion calls contribute flops only (their bytes are the fusion
      boundary, already counted at the call site)
  * total = weighted sum over the ENTRY computation.

Pallas custom-calls: on TPU a pallas_call is an opaque ``custom-call``
with zero visible dots, so the fused coded round used to report ~0 FLOPs.
Each kernel wrapper registers a shape-based FLOP model in
``repro.kernels.ops.KERNEL_COSTS`` keyed by its jitted wrapper name (which
appears in the instruction's ``metadata={op_name=...}``); matching
instructions get the modelled FLOPs (bytes stay with the generic
operands+output accounting — the call boundary IS the HBM round trip).
Unmatched opaque custom-calls are counted in ``custom_calls_uncosted`` so
missing annotations are visible instead of silently zero.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->")
_OP_RE = re.compile(r"^\s*(?:\([^)]*\)|\S+)\s+([\w\-]+)\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERANDS_RE = re.compile(r"\(%([\w\.\-]+)(?:,\s*%([\w\.\-]+))*")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")

def _kernel_cost_registry() -> dict:
    """Lazy import: the analyzer must stay usable without jax/kernels."""
    try:
        from repro.kernels.ops import KERNEL_COSTS
        return KERNEL_COSTS
    except Exception:
        return {}


def _custom_call_flops(rhs: str, shape_str: str,
                       shapes: dict[str, str]) -> tuple[float, bool]:
    """(modelled FLOPs, matched?) for one custom-call instruction."""
    registry = _kernel_cost_registry()
    match = max((k for k in registry if k in rhs), key=len, default=None)
    if match is None:
        return 0.0, False
    args_sec = rhs[rhs.index("(") + 1:] if "(" in rhs else ""
    args_sec = args_sec.split("),")[0]
    operands = []
    for on in re.findall(r"%([\w\.\-]+)", args_sec):
        if on in shapes:
            operands.extend(_dims(shapes[on]))
    if not operands:                     # inline-typed operands only
        operands = _dims(args_sec)
    try:
        return float(registry[match](_dims(shape_str), operands)), True
    except Exception:
        return 0.0, False


_CONTROL_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "token", "iota", "partition-id", "replica-id",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _dims(shape_str):
    out = []
    for m in _SHAPE_TOKEN.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        out.append((dt, d))
    return out


def _bytes(shape_str):
    total = 0
    for dt, d in _dims(shape_str):
        n = 1
        for x in d:
            n *= x
        total += n * _DTYPE_BYTES[dt]
    return total


def analyze_hlo(text: str) -> dict:
    # ---- pass 1: split computations, map op name -> (shape_str, line) ----
    comps: dict[str, list[str]] = {}
    shapes: dict[str, str] = {}
    cur = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr and line.endswith("{"):
            cur = hdr.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        comps[cur].append(line)
        d = _DEF_RE.match(line)
        if d:
            rhs = d.group(2)
            # shape is the leading type token(s) before the op name
            shapes[d.group(1)] = rhs.split(" ")[0] if not \
                rhs.startswith("(") else rhs[:rhs.index(")") + 1]

    # ---- pass 2: per-computation local costs + child edges ----
    local = {c: {"flops": 0.0, "bytes": 0.0, "wire": 0.0,
                 "cc_costed": 0.0, "cc_uncosted": 0.0,
                 "wire_by_kind": defaultdict(float),
                 "coll_counts": defaultdict(int)}
             for c in comps}
    children: dict[str, list[tuple[str, float, bool]]] = \
        {c: [] for c in comps}  # (child, multiplier, flops_only)
    fusion_bodies: set[str] = set()

    for cname, lines in comps.items():
        for line in lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            name, rhs = d.group(1), d.group(2)
            shape_str = shapes.get(name, "")
            opm = _OP_RE.match(rhs)
            op = opm.group(1) if opm else ""

            if op == "custom-call":
                # Pallas kernels (opaque: no dots inside): modelled FLOPs
                # from the per-kernel registry, matched via metadata op_name
                cc_flops, matched = _custom_call_flops(rhs, shape_str,
                                                       shapes)
                if matched:
                    local[cname]["flops"] += cc_flops
                    local[cname]["cc_costed"] += 1
                elif not _CALLS_RE.search(rhs):
                    local[cname]["cc_uncosted"] += 1

            if op in ("dot", "convolution"):
                out_elems = 1
                for _, dd in _dims(shape_str):
                    for x in dd:
                        out_elems *= x
                contracted = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                # lhs shape: typed inline operand ("dot(f32[a,b] %x, ...)",
                # newer HLO printers) or looked up by name ("dot(%x, ...)")
                lhs_dims = []
                if "(" in rhs:
                    lhs_dims = _dims(rhs[rhs.index("(") + 1:])[:1]
                if not lhs_dims:
                    oper = re.search(r"\(%([\w\.\-]+)", rhs)
                    if oper and oper.group(1) in shapes:
                        lhs_dims = _dims(shapes[oper.group(1)])[:1]
                if cm and lhs_dims:
                    dd = lhs_dims[0][1]
                    for i in (cm.group(1).split(",")
                              if cm.group(1) else []):
                        if i and int(i) < len(dd):
                            contracted *= dd[int(i)]
                local[cname]["flops"] += 2.0 * out_elems * contracted

            if op == "while":
                body = _CALLS_RE.search(rhs)
                cond = _COND_RE.search(rhs)
                trip = _TRIP_RE.search(rhs)
                n = float(trip.group(1)) if trip else 1.0
                if body:
                    children[cname].append((body.group(1), n, False))
                if cond:
                    children[cname].append((cond.group(1), n, False))
            elif op in ("fusion", "call", "custom-call", "reduce", "scatter",
                        "sort", "map", "conditional", "select-and-scatter",
                        "reduce-window", "all-reduce", "reduce-scatter"):
                cm = _CALLS_RE.search(rhs)
                if cm and cm.group(1) in comps:
                    if op == "fusion":
                        fusion_bodies.add(cm.group(1))
                        children[cname].append((cm.group(1), 1.0, True))
                    elif op in ("call", "conditional"):
                        children[cname].append((cm.group(1), 1.0, False))
                    else:
                        # scalar lambdas (reduce combiner etc.): negligible
                        fusion_bodies.add(cm.group(1))

            # ---- bytes: top-level kernels only ----
            if op and op not in _CONTROL_OPS and op != "while":
                if op in ("dynamic-update-slice", "scatter"):
                    # in-place (aliased/donated) updates: traffic is the
                    # update region, not the whole buffer
                    ops_ = re.findall(r"%([\w\.\-]+)",
                                      rhs.split("),")[0])
                    upd = _bytes(shapes.get(ops_[1], "")) if \
                        len(ops_) > 1 else 0
                    local[cname]["bytes"] += 2 * upd
                else:
                    b = _bytes(shape_str)
                    for on in re.findall(r"%([\w\.\-]+)",
                                         rhs.split("),")[0]):
                        if on in shapes and on != name:
                            b += _bytes(shapes[on])
                    local[cname]["bytes"] += b

            # ---- collectives (count -start, skip -done) ----
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                bytes_ = _bytes(shape_str)
                gm = _GROUPS_RE.search(rhs)
                if gm:
                    k = len(gm.group(1).split(","))
                else:
                    gi = _GROUPS_IOTA_RE.search(rhs)
                    k = int(gi.group(2)) if gi else 2
                k = max(k, 2)
                if base == "all-gather":
                    wire = bytes_ * (k - 1) / k
                elif base == "all-reduce":
                    wire = 2 * bytes_ * (k - 1) / k
                elif base == "reduce-scatter":
                    wire = bytes_ * (k - 1)
                elif base == "all-to-all":
                    wire = bytes_ * (k - 1) / k
                else:
                    wire = bytes_
                local[cname]["wire"] += wire
                local[cname]["wire_by_kind"][base] += wire
                local[cname]["coll_counts"][base] += 1

    # fusion bodies: their bytes are the fusion boundary (already counted)
    for f in fusion_bodies:
        if f in local:
            local[f]["bytes"] = 0.0

    # ---- pass 3: weighted totals from ENTRY ----
    memo: dict[tuple[str, bool], dict] = {}

    def total(c: str, flops_only: bool) -> dict:
        key = (c, flops_only)
        if key in memo:
            return memo[key]
        memo[key] = {"flops": 0.0, "bytes": 0.0, "wire": 0.0,
                     "cc_costed": 0.0, "cc_uncosted": 0.0,
                     "wire_by_kind": defaultdict(float),
                     "coll_counts": defaultdict(float)}  # cycle guard
        loc = local[c]
        acc = {"flops": loc["flops"],
               "cc_costed": loc["cc_costed"],
               "cc_uncosted": loc["cc_uncosted"],
               "bytes": 0.0 if flops_only else loc["bytes"],
               "wire": 0.0 if flops_only else loc["wire"],
               "wire_by_kind": defaultdict(
                   float, {} if flops_only else dict(loc["wire_by_kind"])),
               "coll_counts": defaultdict(
                   float, {} if flops_only else dict(loc["coll_counts"]))}
        for child, mult, f_only in children.get(c, []):
            if child not in comps:
                continue
            sub = total(child, flops_only or f_only)
            acc["flops"] += mult * sub["flops"]
            acc["bytes"] += mult * sub["bytes"]
            acc["wire"] += mult * sub["wire"]
            acc["cc_costed"] += mult * sub["cc_costed"]
            acc["cc_uncosted"] += mult * sub["cc_uncosted"]
            for k, v in sub["wire_by_kind"].items():
                acc["wire_by_kind"][k] += mult * v
            for k, v in sub["coll_counts"].items():
                acc["coll_counts"][k] += mult * v
        memo[key] = acc
        return acc

    if entry is None:
        raise ValueError("no ENTRY computation found")
    result = total(entry, False)
    return {
        "flops": result["flops"],
        "bytes": result["bytes"],
        "wire_bytes": result["wire"],
        "wire_by_kind": dict(result["wire_by_kind"]),
        "collective_counts": dict(result["coll_counts"]),
        "custom_calls_costed": result["cc_costed"],
        "custom_calls_uncosted": result["cc_uncosted"],
    }
