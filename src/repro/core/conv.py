"""Convolution via the paper's GEMM transformation (Fig. 4) + channel-split CDC.

The paper implements CDC *below* the framework, at the GEMM level, by first
unrolling conv into O = W[K, F*F*C] @ I[F*F*C, W*H] (Eq. 4). Channel splitting
divides W along K (the output/filter axis) -- identical algebra to
fully-connected output splitting (paper Fig. 8) -- so ``coded_matmul`` applies
unchanged to the unrolled weights. This module provides the unroll (im2col)
and the coded conv wrapper used by tests/benchmarks and the whisper-style
conv stub.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coded_layer import CodedDenseSpec, coded_matmul

__all__ = ["im2col", "conv2d_gemm", "coded_conv2d"]


def im2col(x: jax.Array, f: int, stride: int = 1,
           padding: str = "SAME") -> jax.Array:
    """Unroll input patches (paper Fig. 4a).

    x: [N, H, W, C] -> [N, Ho*Wo, F*F*C] (patches as GEMM columns).
    """
    n, h, w, c = x.shape
    if padding == "SAME":
        pad = ((f - 1) // 2, f // 2)
        x = jnp.pad(x, ((0, 0), pad, pad, (0, 0)))
        ho, wo = -(-h // stride), -(-w // stride)
    else:
        ho = (h - f) // stride + 1
        wo = (w - f) // stride + 1
    # Extract f*f shifted views; static python loop (f is small & static).
    cols = []
    for di in range(f):
        for dj in range(f):
            cols.append(jax.lax.dynamic_slice(
                x, (0, di, dj, 0), (n, (ho - 1) * stride + 1,
                                    (wo - 1) * stride + 1, c)
            )[:, ::stride, ::stride, :])
    patches = jnp.stack(cols, axis=3)  # [N, Ho, Wo, F*F, C]
    return patches.reshape(n, ho * wo, f * f * c)


def conv2d_gemm(x: jax.Array, filters: jax.Array, stride: int = 1,
                padding: str = "SAME") -> jax.Array:
    """Conv as GEMM (paper Eq. 4). filters: [F, F, C, K]; x: [N, H, W, C]."""
    f, _, c, k = filters.shape
    n, h, w, _ = x.shape
    cols = im2col(x, f, stride, padding)  # [N, P, F*F*C]
    wmat = filters.reshape(f * f * c, k)  # [F*F*C, K]
    out = cols @ wmat  # [N, P, K]
    ho = cols.shape[1] // (-(-w // stride)) if padding == "SAME" else \
        (h - f) // stride + 1
    wo = cols.shape[1] // ho
    return out.reshape(n, ho, wo, k)


def coded_conv2d(x: jax.Array, filters: jax.Array, w_cdc: jax.Array | None,
                 spec: CodedDenseSpec, valid: jax.Array | None = None,
                 stride: int = 1, padding: str = "SAME",
                 **kw) -> jax.Array:
    """Channel-split conv with CDC over the filter/output axis K.

    w_cdc comes from ``make_parity_weights(filters.reshape(F*F*C, K), spec)``
    -- offline, exactly like the fc case.
    """
    f, _, c, k = filters.shape
    n, h, w, _ = x.shape
    cols = im2col(x, f, stride, padding)  # [N, P, F*F*C]
    wmat = filters.reshape(f * f * c, k)
    out = coded_matmul(cols, wmat, w_cdc, spec, valid, **kw)  # [N, P, K]
    ho = -(-h // stride) if padding == "SAME" else (h - f) // stride + 1
    wo = out.shape[1] // ho
    return out.reshape(n, ho, wo, k)
