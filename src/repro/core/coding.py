"""CDC erasure codes over output-split GEMM shards (paper §5.2-5.3, §7).

The paper's code: for an output-split GEMM with T weight shards W_1..W_T
(split along the output dim), one parity shard W_cdc = sum_i W_i is computed
OFFLINE (input-independent). At runtime each shard output Y_i = X @ W_i and the
parity output Y_cdc = X @ W_cdc satisfy Y_cdc = sum_i Y_i, so a single missing
Y_m is recovered by a local subtraction (Eq. 6-7, Eq. 11-12).

Beyond the paper (§7 only sketches >1 failure): we generalize to r parity
shards with a real-valued MDS generator (Vandermonde on positive nodes, which
is totally positive => every square minor is nonsingular => any r erasures are
decodable). r=1 with the all-ones row is exactly the paper's sum code.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CodeSpec",
    "generator_matrix",
    "encode_weights",
    "encode_outputs",
    "decode_outputs",
    "max_decode_condition",
]


@dataclasses.dataclass(frozen=True)
class CodeSpec:
    """An (T + r, T) systematic erasure code over GEMM output shards.

    Attributes:
      n_shards: T, number of data shards (devices doing real output splits).
      n_parity: r, number of parity shards. r=1 => the paper's sum code.
      parity_dtype: accumulation dtype for parity math (fp32 recommended when
        shard outputs are bf16; see DESIGN.md §8).
    """

    n_shards: int
    n_parity: int = 1
    parity_dtype: jnp.dtype | None = jnp.float32

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if not (0 <= self.n_parity <= self.n_shards):
            raise ValueError(
                f"n_parity must be in [0, n_shards], got {self.n_parity}")

    @property
    def total_shards(self) -> int:
        return self.n_shards + self.n_parity

    @functools.cached_property
    def generator(self) -> np.ndarray:
        return generator_matrix(self.n_shards, self.n_parity)


def generator_matrix(n_shards: int, n_parity: int) -> np.ndarray:
    """(r, T) parity generator. Row j holds the combination coefficients.

    r=1: all-ones (the paper's W_cdc = sum_i W_i).
    r>1: Vandermonde rows c[j, i] = x_i**j with strictly positive increasing
    nodes x_i in (0, 2]. A Vandermonde matrix on positive increasing nodes is
    totally positive, so every e x e minor (any e parities x any e missing
    shards, e <= r) is nonsingular -- the code is MDS over the reals.
    """
    if n_parity == 0:
        return np.zeros((0, n_shards), dtype=np.float64)
    # Geometrically spaced nodes in [1/2, 2]: strictly positive & increasing
    # (total positivity => MDS), bounded powers (no fp32 under/overflow), and
    # a guaranteed multiplicative gap between nodes so every small decode
    # submatrix stays well-conditioned in fp32 for the r <= 4 regime.
    i = np.arange(n_shards, dtype=np.float64)
    nodes = 2.0 ** (2.0 * i / max(n_shards - 1, 1) - 1.0) \
        if n_shards > 1 else np.ones(1)
    powers = np.arange(n_parity, dtype=np.float64)[:, None]
    gen = nodes[None, :] ** powers  # row 0 is all-ones -> paper's sum code
    gen = gen / gen.max(axis=1, keepdims=True)  # row scale ~1 (row 0 intact)
    return gen


def max_decode_condition(spec: CodeSpec) -> float:
    """Worst-case condition number over all full-r erasure patterns.

    Checked at encode time (offline) so ill-conditioned (T, r) combos are
    rejected before deployment, mirroring the paper's offline weight prep.
    Exhaustive for small T, sampled otherwise.
    """
    import itertools

    if spec.n_parity == 0:
        return 1.0
    gen = spec.generator
    worst = 1.0
    combos = itertools.combinations(range(spec.n_shards), spec.n_parity)
    for n, missing in enumerate(combos):
        sub = gen[:, list(missing)]
        worst = max(worst, float(np.linalg.cond(sub)))
        if n > 2000:  # sampled bound for very large T
            break
    return worst


def encode_weights(w_shards: jax.Array, spec: CodeSpec) -> jax.Array:
    """Offline parity-weight construction (paper Eq. 7 / Eq. 11).

    Args:
      w_shards: [T, ..., m_shard] stacked weight shards (output dim last or
        anywhere -- coding acts only on the stacking axis).
      spec: code spec with spec.n_shards == T.

    Returns:
      [r, ..., m_shard] parity weights W_cdc[j] = sum_i gen[j, i] * W_i.
    """
    if w_shards.shape[0] != spec.n_shards:
        raise ValueError(
            f"w_shards leading dim {w_shards.shape[0]} != T={spec.n_shards}")
    gen = jnp.asarray(spec.generator, dtype=spec.parity_dtype or w_shards.dtype)
    acc = jnp.tensordot(gen, w_shards.astype(gen.dtype), axes=[[1], [0]])
    return acc.astype(w_shards.dtype)


def encode_outputs(y_shards: jax.Array, spec: CodeSpec) -> jax.Array:
    """Runtime parity of shard outputs (used by oracles/tests; in production
    the parity output comes from the parity *weights*, never from gathering
    all shard outputs -- that is the whole point of the code)."""
    dtype = spec.parity_dtype or y_shards.dtype
    gen = jnp.asarray(spec.generator, dtype=dtype)
    return jnp.tensordot(gen, y_shards.astype(dtype), axes=[[1], [0]])


def decode_outputs(
    y_shards: jax.Array,
    parity: jax.Array,
    valid: jax.Array,
    spec: CodeSpec,
) -> jax.Array:
    """Recover erased shard outputs (paper Eq. 12 for r=1; MDS solve for r>1).

    Fully jit-compatible: static shapes, erasure pattern is a runtime mask.

    Args:
      y_shards: [T, ...] shard outputs; erased entries may hold garbage.
      parity:   [r, ...] parity outputs (from the parity weights).
      valid:    [T] bool; False marks an erased shard. At most r False.
      spec:     the code.

    Returns:
      [T, ...] outputs with erased shards reconstructed. Exact in exact
      arithmetic; see DESIGN.md §8 for float error bounds.
    """
    T, r = spec.n_shards, spec.n_parity
    if r == 0:
        return y_shards
    dtype = spec.parity_dtype or y_shards.dtype
    y = jnp.where(valid.reshape((T,) + (1,) * (y_shards.ndim - 1)),
                  y_shards.astype(dtype), 0)
    gen = jnp.asarray(spec.generator, dtype=dtype)  # [r, T]

    if r == 1:
        # Paper's fast path: y_miss = parity - sum_valid y (Eq. 12).
        missing_val = parity[0].astype(dtype) - jnp.sum(y, axis=0)
        rec = jnp.where(valid.reshape((T,) + (1,) * (y.ndim - 1)),
                        y, missing_val[None])
        return rec.astype(y_shards.dtype)

    # MDS path: solve an r x r system for up to r erased shards.
    # residual_j = parity_j - sum_{i valid} gen[j,i] y_i = sum_{i missing} gen[j,i] y_i
    residual = parity.astype(dtype) - jnp.tensordot(gen, y, axes=[[1], [0]])
    # Static-shape selection of (up to) r missing indices; slots beyond the
    # actual erasure count are padded with valid indices whose equations are
    # replaced by identity rows (harmless).
    miss_score = jnp.where(valid, -1.0, 1.0)
    _, miss_idx = jax.lax.top_k(miss_score, r)  # [r] indices, erased first
    is_real = ~valid[miss_idx]  # [r] whether slot holds a true erasure
    # A[j, s] = gen[j, miss_idx[s]] for real slots; identity for padded slots.
    A = gen[:, miss_idx]  # [r, r]
    eye = jnp.eye(r, dtype=dtype)
    A = jnp.where(is_real[None, :], A, eye)
    rhs = jnp.where(is_real.reshape((r,) + (1,) * (residual.ndim - 1)),
                    residual, 0)
    flat_rhs = rhs.reshape(r, -1)
    sol = jnp.linalg.solve(A, flat_rhs).reshape(rhs.shape)  # [r, ...]
    # Scatter solutions back into the erased slots.
    rec = y
    upd = jnp.where(is_real.reshape((r,) + (1,) * (sol.ndim - 1)), sol, 0)
    rec = rec.at[miss_idx].add(upd)
    return rec.astype(y_shards.dtype)
