"""One root seed, many independent deterministic streams.

A chaos run draws randomness in several places — the scheduler's modelled
straggler latencies, the fault injector's churn process, the injected
latency process — and each must be reproducible bit-exact from a SINGLE
root seed while staying independent of how often the *other* streams
draw. Deriving every consumer's rng as ``stream_rng(root, name)`` gives
exactly that: the stream is keyed by (root, name), so adding a draw to
one component never perturbs another, and re-running with the same root
replays the identical fault schedule, latencies, and planner inputs.

Lives in ``repro.core`` (no runtime/faults dependencies) so both the
runtime scheduler and the faults package can use it without a package
cycle; ``repro.faults.seeds`` re-exports it as part of the chaos API.
"""
from __future__ import annotations

import zlib

import numpy as np


def stream_seed(root: int, name: str) -> np.random.SeedSequence:
    """A SeedSequence for the named stream under ``root``."""
    return np.random.SeedSequence(
        [int(root) & 0xFFFFFFFF, zlib.crc32(name.encode("utf-8"))])


def stream_rng(root: int, name: str) -> np.random.Generator:
    """An independent Generator for the named stream under ``root``.

    Same (root, name) -> bit-identical draw sequence; different names (or
    roots) -> statistically independent streams.
    """
    return np.random.default_rng(stream_seed(root, name))
