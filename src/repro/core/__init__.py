"""repro.core -- the paper's contribution: CDC-coded model-parallel inference.

Public surface:
  CodeSpec, generator_matrix, encode_weights, decode_outputs   (coding algebra)
  CodedDenseSpec, coded_matmul, make_parity_weights, pad_for_code (coded GEMM)
  conv2d_gemm, coded_conv2d                                      (conv/channel split)
  SplitMethod, TABLE_1, suitability_table                        (Table-1 policy)
  StragglerModel, mitigation_improvement, coverage_*             (failure models)
"""
from repro.core.coding import (CodeSpec, decode_outputs, encode_outputs,
                               encode_weights, generator_matrix,
                               max_decode_condition)
from repro.core.coded_layer import (CodedDenseSpec, coded_matmul,
                                    decode_and_merge, decode_folded,
                                    fold_parity_slots, folded_slot_map,
                                    make_parity_weights, merge_shards,
                                    pad_for_code, unfold_parity)
from repro.core.conv import coded_conv2d, conv2d_gemm, im2col
from repro.core.failure import (StragglerModel, coverage_2mr,
                                coverage_at_budget, mitigation_improvement,
                                request_latency, sample_erasures)
from repro.core.policy import (ALL_METHODS, TABLE_1, SplitMethod,
                               suitability_table)
