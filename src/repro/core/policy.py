"""Table 1 of the paper: which model-parallel splits admit CDC coding.

A split is suitable iff the parity computation can be derived OFFLINE from
weights alone -- i.e. the split divides the WEIGHT matrix and the OUTPUT but
leaves the INPUT whole. Splits that divide the input would need runtime sums
of activations (2x compute, paper §5.3) or share no factor at all.
"""
from __future__ import annotations

import dataclasses
import enum


class Layer(enum.Enum):
    FC = "fc"
    CONV = "conv"


@dataclasses.dataclass(frozen=True)
class SplitMethod:
    name: str
    layer: Layer
    divides_input: bool
    divides_weight: bool
    divides_output: bool

    @property
    def suitable_for_cdc(self) -> bool:
        """Paper Table 1: suitable <=> splits weights/output, not input."""
        return (self.divides_weight and self.divides_output
                and not self.divides_input)

    @property
    def why(self) -> str:
        if self.suitable_for_cdc:
            return ("parity weights are input-independent column sums, "
                    "computed offline; parity work is shaped like shard work")
        if self.divides_input and self.divides_weight:
            return ("partial sums share no factor between devices (paper "
                    "Eq. 13-14); a parity device would redo the entire GEMM")
        if self.divides_input:
            return ("parity over inputs must be summed at runtime "
                    "(2x compute) because activations change per request")
        return "does not divide weights; nothing to encode offline"


# The five methods of paper §4, with the division pattern of §5.1.
OUTPUT_SPLIT = SplitMethod("output", Layer.FC, False, True, True)
INPUT_SPLIT = SplitMethod("input", Layer.FC, True, True, False)
CHANNEL_SPLIT = SplitMethod("channel", Layer.CONV, False, True, True)
SPATIAL_SPLIT = SplitMethod("spatial", Layer.CONV, True, False, True)
FILTER_SPLIT = SplitMethod("filter", Layer.CONV, True, True, True)

ALL_METHODS = (OUTPUT_SPLIT, INPUT_SPLIT, CHANNEL_SPLIT, SPATIAL_SPLIT,
               FILTER_SPLIT)

# Expected verdicts straight from Table 1 -- tests assert the predicate
# reproduces the paper's column.
TABLE_1 = {
    "output": True,
    "input": False,
    "channel": True,
    "spatial": False,
    "filter": False,
}


def suitability_table() -> list[dict]:
    return [
        {
            "layer": m.layer.value,
            "method": m.name,
            "divides_input": m.divides_input,
            "divides_weight": m.divides_weight,
            "divides_output": m.divides_output,
            "suitable": m.suitable_for_cdc,
            "why": m.why,
        }
        for m in ALL_METHODS
    ]
