"""CDC-coded column-parallel (output-split) GEMM.

This is the paper's contribution as a composable JAX primitive. A coded dense
layer owns:
  w      [k, m]              the ordinary weight, column-sharded over `model`
  w_cdc  [T, k, r*m_l/T]     folded parity weights (slot-major, staggered), or
         [r, k, m_l]         dedicated parity weights (paper layout)
with m_l = m / T. Parity weights are computed OFFLINE from w (paper §5.2:
"the summation of the weights ... is not dependent on inputs").

Two placements (DESIGN.md §2):
  * ``dedicated`` -- the paper's +r-devices scheme: parity shards live on
    their own shard slots (natural across a DCN/pod axis, or test meshes of
    size T+r). Tolerates r erasures at +r/T compute.
  * ``folded`` -- TPU-native: each of the T devices computes its data shard
    plus a 1/T slice of every parity shard, with slice->device assignment
    STAGGERED so one device failure destroys at most one parity equation per
    output column. Tolerates floor(r/2) whole-device failures (r=2 covers the
    paper's single-failure case) at +r/T compute, on an unmodified 2^k mesh.

All math is expressed as plain jnp ops over an explicit shard dimension, so it
runs identically on one CPU device (smoke tests / oracles) and under GSPMD on
a production mesh (``dist.sharding`` pins the layouts); a shard_map wrapper
with explicit per-device placement lives in ``dist.collectives``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coding
from repro.core.coding import CodeSpec

__all__ = [
    "CodedLayout",
    "pad_for_code",
    "make_parity_weights",
    "fold_parity_slots",
    "unfold_parity",
    "folded_slot_map",
    "coded_matmul",
    "decode_folded",
    "decode_and_merge",
    "merge_shards",
    "CodedDenseSpec",
]


@dataclasses.dataclass(frozen=True)
class CodedDenseSpec:
    """Static description of one coded GEMM."""

    code: CodeSpec
    layout: str = "folded"  # "folded" | "dedicated"

    def __post_init__(self):
        if self.layout not in ("folded", "dedicated"):
            raise ValueError(self.layout)
        if self.layout == "folded" and self.code.n_parity > 0:
            # folded slices must divide the shard width; checked at encode.
            pass

    @property
    def max_device_failures(self) -> int:
        if self.code.n_parity == 0:
            return 0
        if self.layout == "dedicated":
            return self.code.n_parity
        return self.code.n_parity // 2


CodedLayout = CodedDenseSpec  # alias


def _fused_enabled(use_fused: bool | str) -> bool:
    """The shared fused-kernel policy: ``"auto"`` enables the Pallas path
    only where it compiles natively (TPU); True forces it (interpret mode
    elsewhere — the conformance suites); False is the plain-jnp reference."""
    if use_fused == "auto":
        return jax.default_backend() == "tpu"
    return bool(use_fused)


def pad_for_code(m: int, n_shards: int, align: int = 8) -> int:
    """Round output dim up so m % (T*T*align) == 0 (shard width divides into
    T aligned parity slices). align=128 for MXU-friendly production dims."""
    q = n_shards * n_shards * align
    return ((m + q - 1) // q) * q


def make_parity_weights(w: jax.Array, spec: CodedDenseSpec) -> jax.Array:
    """Offline encode. w: [k, m] -> dedicated [r, k, m_l] or folded slots
    [T, k, r*m_l/T]."""
    code = spec.code
    T, r = code.n_shards, code.n_parity
    if w.ndim == 3:  # stacked layers [L, k, m] (scan-over-layers params)
        import jax as _jax
        return _jax.vmap(lambda wi: make_parity_weights(wi, spec))(w)
    k, m = w.shape
    if m % T:
        raise ValueError(f"output dim {m} not divisible by T={T}; "
                         f"pad with pad_for_code() first")
    m_l = m // T
    shards = jnp.moveaxis(w.reshape(k, T, m_l), 1, 0)  # [T, k, m_l]
    parity = coding.encode_weights(shards, code)  # [r, k, m_l]
    if spec.layout == "dedicated":
        return parity
    return fold_parity_slots(parity, T)


def folded_slot_map(T: int, r: int) -> np.ndarray:
    """slot_map[j, s] = device slot holding slice s of parity j (staggered).

    Chosen so slot d computes slice (d - j - 1) mod T of parity j: a failure
    of device d erases, for each output column, at most ONE parity equation
    (the one whose slice landed on d), never the same one twice.
    """
    j = np.arange(r)[:, None]
    s = np.arange(T)[None, :]
    return (s + j + 1) % T


def fold_parity_slots(parity: jax.Array, T: int) -> jax.Array:
    """[r, k, m_l] -> [T, k, r*w] slot-major staggered layout, w = m_l/T."""
    r, k, m_l = parity.shape
    if m_l % T:
        raise ValueError(f"shard width {m_l} not divisible by T={T} "
                         f"(pad_for_code)")
    w = m_l // T
    sliced = parity.reshape(r, k, T, w)  # [r, k, s, w]
    smap = folded_slot_map(T, r)  # [r, T]
    # slot d, parity j holds slice s where smap[j, s] == d  =>  s = (d - j - 1) % T
    j = np.arange(r)[:, None]
    d = np.arange(T)[None, :]
    s_of = (d - j - 1) % T  # [r, T] slice index for (j, slot)
    # gather: out[d, k, j, w] = sliced[j, k, s_of[j, d], w]
    out = sliced[j[:, 0][:, None, None, None],
                 np.arange(k)[None, :, None, None],
                 s_of[:, None, :, None],
                 np.arange(w)[None, None, None, :]]  # [r, k, T, w]
    out = jnp.moveaxis(out, 2, 0)  # [T, r, k, w] -> want [T, k, r*w]
    out = jnp.moveaxis(out, 1, 2).reshape(T, k, r * w)
    return out


def unfold_parity(p_slots: jax.Array, T: int, r: int) -> jax.Array:
    """Inverse of the slot layout for *outputs*: [T, ..., r*w] -> [r, ..., m_l].

    p_slots[d][..., j*w:(j+1)*w] is slice (d-j-1)%T of parity j.
    """
    w = p_slots.shape[-1] // r
    parts = p_slots.reshape(p_slots.shape[:-1] + (r, w))  # [T, ..., r, w]
    parts = jnp.moveaxis(parts, -2, 1)  # [T, r, ..., w]
    smap = folded_slot_map(T, r)  # slot holding slice s of parity j
    # parity[j, s] = parts[smap[j, s], j]
    gathered = parts[jnp.asarray(smap), jnp.arange(r)[:, None]]  # [r, T, ..., w]
    # reassemble slices along the last dim: [r, ..., T*w]
    gathered = jnp.moveaxis(gathered, 1, -2)  # [r, ..., T, w]
    return gathered.reshape(gathered.shape[:-2] + (T * w,))


def _shardwise_matmul(x: jax.Array, w_stacked: jax.Array,
                      dtype=None) -> jax.Array:
    """y[d] = x @ w_stacked[d];  x: [..., k], w: [T, k, c] -> [T, ..., c]."""
    return jnp.einsum("...k,dkc->d...c", x, w_stacked,
                      preferred_element_type=dtype or x.dtype)


def merge_shards(ys: jax.Array) -> jax.Array:
    """[T, ..., m_l] stacked shard outputs -> merged [..., T*m_l]."""
    y = jnp.moveaxis(ys, 0, -2)
    return y.reshape(y.shape[:-2] + (y.shape[-2] * y.shape[-1],))


def decode_and_merge(
    ys: jax.Array,
    parity: jax.Array | None,
    spec: CodedDenseSpec,
    valid: jax.Array | None,
    *,
    valid_parity: jax.Array | None = None,
    acc_dtype=jnp.float32,
    use_fused: bool | str = False,
) -> jax.Array:
    """Recovery + merge given already-computed shard outputs.

    The tail of ``coded_matmul`` — shared with ``dist.collectives``, where
    ``ys``/``parity`` arrive from an all_gather over the `model` axis
    instead of a local stacked einsum. Erased entries of ``ys`` (and, for
    the folded layout, dead slots of ``parity``) may hold garbage; they
    are masked here before the decode. Dedicated-layout parity rows are
    assumed INTACT: ``coding.decode_outputs`` solves with all r equations
    and has no equation-selection for a lost parity message (the folded
    path does, via ``valid_parity``) — dedicated callers must deliver
    parity from healthy workers (coded_matmul recomputes it locally).

    ys:     [T, ..., m_l] data-shard outputs.
    parity: [r, ..., m_l] (dedicated) or [T, ..., r*w] slots (folded);
            None => plain merge.
    """
    code = spec.code
    T = code.n_shards
    if parity is None or code.n_parity == 0 or valid is None:
        return merge_shards(ys)
    if _fused_enabled(use_fused):
        from repro.kernels import ops  # deferred: kernels import this module
        return ops.fused_decode_merge(ys, parity, spec, valid,
                                      valid_parity=valid_parity)
    if valid_parity is None:
        valid_parity = valid
    vshape = (T,) + (1,) * (ys.ndim - 1)
    ys = jnp.where(valid.reshape(vshape), ys, 0)
    if spec.layout == "dedicated":
        rec = coding.decode_outputs(ys, parity, valid, code)
    else:
        pshape = (T,) + (1,) * (parity.ndim - 1)
        p_slots = jnp.where(valid_parity.reshape(pshape), parity, 0)
        rec = decode_folded(ys, p_slots, valid, code,
                            valid_parity=valid_parity, acc_dtype=acc_dtype)
    return merge_shards(rec)


def coded_matmul(
    x: jax.Array,
    w: jax.Array,
    w_cdc: jax.Array | None,
    spec: CodedDenseSpec,
    valid: jax.Array | None = None,
    *,
    valid_parity: jax.Array | None = None,
    acc_dtype=jnp.float32,
    use_fused: bool | str = False,
) -> jax.Array:
    """Output-split GEMM with CDC protection (paper Eq. 7/11 + recovery 12).

    Args:
      x: [..., k] activations (replicated over the model axis).
      w: [k, m] weights (column-sharded over the model axis).
      w_cdc: parity weights from ``make_parity_weights`` (None => uncoded).
      spec: code + layout.
      valid: [T] bool device-validity mask (None => all valid). Erased shards'
        contributions are zeroed (simulating the lost message / dead device)
        and reconstructed from parity.
      valid_parity: validity of the parity *messages*. Defaults to ``valid``
        (whole-device failure: a dead device loses its data shard AND its
        folded parity slices). Pass all-ones for the message-erasure model,
        where r=1 folded already recovers a lost data message.
      use_fused: route through the fused Pallas kernel
        (``kernels.ops.fused_coded_matmul``): shard GEMMs + Eq. 12 decode +
        merge in one kernel, no per-shard HBM round-trips. ``"auto"`` =
        native TPU only; True forces (interpret elsewhere); False (default)
        = this reference path. The fused kernel covers the <=1-erasure
        regime and falls back here beyond it.

    Returns:
      [..., m] the full (merged) output, identical to x @ w when all shards
      are valid, and still identical (up to float eps) under <= f erasures.
    """
    code = spec.code
    T = code.n_shards
    if w_cdc is not None and code.n_parity > 0 and valid is not None \
            and _fused_enabled(use_fused):
        from repro.kernels import ops  # deferred: kernels import this module
        return ops.fused_coded_matmul(x, w, w_cdc, spec, valid,
                                      valid_parity=valid_parity)
    k, m = w.shape
    m_l = m // T
    w_st = jnp.moveaxis(w.reshape(k, T, m_l), 1, 0)  # [T, k, m_l]
    ys = _shardwise_matmul(x, w_st)  # [T, ..., m_l]

    if w_cdc is None or code.n_parity == 0 or valid is None:
        return merge_shards(ys)  # uncoded (or nothing to recover)

    parity = _shardwise_matmul(x, w_cdc)  # dedicated [r,...,m_l] | slots
    return decode_and_merge(ys, parity, spec, valid,
                            valid_parity=valid_parity, acc_dtype=acc_dtype)


def decode_folded(ys: jax.Array, p_slots: jax.Array, valid: jax.Array,
                  code: CodeSpec, *, valid_parity: jax.Array | None = None,
                  acc_dtype=jnp.float32) -> jax.Array:
    """Recover erased data shards under the folded/staggered placement.

    ys:      [T, ..., m_l] data-shard outputs (erased entries zeroed).
    p_slots: [T, ..., r*w] parity outputs in slot layout (erased zeroed).
    valid:   [T] device validity; at most floor(r/2) False.

    Per output column in slice s, the parity equations still alive are those
    j with valid[slot_map[j, s]]; each failed device kills exactly one
    equation per column. We solve, per slice, an f x f system (f = max
    failures) with the same static-shape top_k selection as
    ``coding.decode_outputs``.
    """
    T, r = code.n_shards, code.n_parity
    f = max(r // 2, 1)
    m_l = ys.shape[-1]
    w = m_l // T
    dtype = acc_dtype or ys.dtype
    if valid_parity is None:
        valid_parity = valid

    parity = unfold_parity(p_slots, T, r).astype(dtype)  # [r, ..., m_l]
    gen = jnp.asarray(code.generator, dtype=dtype)  # [r, T]
    y = ys.astype(dtype)

    # residual_j = parity_j - sum_{i valid} gen[j,i] y_i  (valid y already
    # zeroed-out for dead i, so plain tensordot works)
    residual = parity - jnp.tensordot(gen, y, axes=[[1], [0]])  # [r, ..., m_l]

    smap = jnp.asarray(folded_slot_map(T, r))  # [r, T(slices)]
    pv = valid_parity[smap]  # [r, T] parity validity per slice

    # unknowns: up to f missing data shards (same for every slice/column)
    miss_score = jnp.where(valid, -1.0, 1.0)
    _, miss_idx = jax.lax.top_k(miss_score, f)  # [f]
    is_real = ~valid[miss_idx]  # [f]

    # equations: per slice, pick f valid parity rows (prefer low j)
    eq_score = jnp.where(pv, 1.0, -1.0) \
        - jnp.arange(r, dtype=jnp.float32)[:, None] * 1e-3
    _, eq_idx = jax.lax.top_k(eq_score.T, f)  # [T(slices), f]

    # per-slice f x f system: A[s, e, u] = gen[eq_idx[s,e], miss_idx[u]]
    A = gen[eq_idx][..., miss_idx]  # [S, f, f]
    eye = jnp.eye(f, dtype=dtype)
    A = jnp.where(is_real[None, None, :], A, eye[None])

    # rhs: residual of the selected equations, per slice
    res_sliced = residual.reshape((r,) + residual.shape[1:-1] + (T, w))
    res_sliced = jnp.moveaxis(res_sliced, -2, 1)  # [r, S, ..., w]
    rhs = jnp.take_along_axis(
        res_sliced, eq_idx.T.reshape((f, T) + (1,) * (res_sliced.ndim - 2)),
        axis=0)  # [f, S, ..., w]
    rhs = jnp.where(is_real.reshape((f,) + (1,) * (rhs.ndim - 1)), rhs, 0)

    # solve per slice: [S, f, f] @ sol[S, f, K] = rhs[S, f, K]
    K = int(np.prod(rhs.shape[2:]))
    rhs_flat = jnp.moveaxis(rhs, 0, 1).reshape(T, f, K)
    sol = jnp.linalg.solve(A, rhs_flat)  # [S, f, K]
    sol = jnp.moveaxis(sol.reshape((T, f) + rhs.shape[2:]), 1, 0)  # [f,S,...,w]

    # scatter the recovered slices back into y[miss_idx]
    upd = jnp.where(is_real.reshape((f,) + (1,) * (sol.ndim - 1)), sol, 0)
    y_sliced = y.reshape(y.shape[:-1] + (T, w))
    y_sliced = jnp.moveaxis(y_sliced, -2, 1)  # [T(shards), S, ..., w]
    y_sliced = y_sliced.at[miss_idx].add(upd)
    y_out = jnp.moveaxis(y_sliced, 1, -2).reshape(y.shape)
    return y_out.astype(ys.dtype)
