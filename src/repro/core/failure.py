"""Failure & straggler models (paper §2 Fig. 1, §6.2 Fig. 14-16).

The container has no real failing hardware, so failures are an *erasure
channel*: a boolean validity mask over shard outputs. The serving layer and
benchmarks draw masks / latencies from the models here; the recovery math in
``coding.decode_outputs`` consumes the masks.

Latency model: the paper's Fig. 1 arrival histogram (RPis over WiFi) is
heavy-tailed past the 50 ms compute floor. We model per-shard response time as
``floor + lognormal`` which reproduces that shape; first-T-of-(T+r) order
statistics then quantify straggler mitigation exactly as §6.2 does.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class StragglerModel:
    """floor + LogNormal(mu, sigma) per-shard latency, iid across shards."""

    floor_ms: float = 50.0     # single-device compute time in the paper
    mu: float = 3.0            # lognormal location (of the tail part, ms)
    sigma: float = 1.0         # heavy tail: ~34% of arrivals past 2x floor

    def sample(self, rng: np.random.Generator, shape) -> np.ndarray:
        return self.floor_ms + rng.lognormal(self.mu, self.sigma, size=shape)


def sample_erasures(rng: np.random.Generator, n_shards: int, p_fail: float,
                    max_erasures: int) -> np.ndarray:
    """Validity mask with iid failures, clipped to the decodable budget."""
    fail = rng.random(n_shards) < p_fail
    if fail.sum() > max_erasures:
        # keep only the first `max_erasures` failures (beyond-budget failures
        # fall back to the paper's degraded-redistribution path)
        idx = np.flatnonzero(fail)[max_erasures:]
        fail[idx] = False
    return ~fail


def request_latency(times: np.ndarray, need: int) -> np.ndarray:
    """Latency of a coded request: the `need`-th order statistic.

    times: [..., n_shards] per-shard response times. With r parity shards the
    combiner proceeds after the fastest T = need arrivals (paper §6.2); the
    uncoded system waits for max(times) over its T shards.
    """
    return np.sort(times, axis=-1)[..., need - 1]


def mitigation_improvement(model: StragglerModel, n_devices: int,
                           n_parity: int = 1, n_trials: int = 20000,
                           seed: int = 0) -> dict:
    """Reproduces Fig. 16b: % latency improvement of first-T-of-(T+r) over
    wait-for-all-T, at equal shard work."""
    rng = np.random.default_rng(seed)
    base = model.sample(rng, (n_trials, n_devices))
    coded = model.sample(rng, (n_trials, n_devices + n_parity))
    lat_base = request_latency(base, n_devices)            # max of T
    lat_coded = request_latency(coded, n_devices)          # T-th of T+r
    return {
        "n_devices": n_devices,
        "mean_uncoded_ms": float(lat_base.mean()),
        "mean_coded_ms": float(lat_coded.mean()),
        "p99_uncoded_ms": float(np.percentile(lat_base, 99)),
        "p99_coded_ms": float(np.percentile(lat_coded, 99)),
        "mean_improvement_pct":
            float(100 * (1 - lat_coded.mean() / lat_base.mean())),
        "p99_improvement_pct":
            float(100 * (1 - np.percentile(lat_coded, 99)
                         / np.percentile(lat_base, 99))),
    }


def coverage_2mr(n_model_parallel: int, n_other: int) -> dict:
    """Paper §6.3 / Fig. 17 economics: devices needed to tolerate 1 failure.

    2MR duplicates every device (linear). CDC covers all n_model_parallel
    devices of a coded layer with ONE extra device (constant); remaining
    devices still need 2MR. Returns extra-device counts and coverage ratios.
    """
    total = n_model_parallel + n_other
    extra_2mr = total                      # duplicate everything
    extra_cdc = 1 + n_other                # 1 parity + 2MR for the rest
    return {
        "devices": total,
        "extra_2mr": extra_2mr,
        "extra_cdc_2mr": extra_cdc,
        "hw_cost_2mr": (total + extra_2mr) / total,          # 2.0x
        "hw_cost_cdc_2mr": (total + extra_cdc) / total,      # (1 + 1/N) on MP part
    }


def coverage_at_budget(n_model_parallel_layers: list[int], n_other: int,
                       extra_budget: int) -> dict:
    """Coverage fraction achievable with a fixed number of extra devices
    (the Fig. 17 bar charts): CDC covers a whole coded layer per extra
    device; 2MR covers one device per extra device."""
    mp_total = sum(n_model_parallel_layers)
    total = mp_total + n_other
    cov_2mr = min(extra_budget, total) / total
    covered = 0
    budget = extra_budget
    # spend on model-parallel layers first (best coverage per device)
    for n in sorted(n_model_parallel_layers, reverse=True):
        if budget <= 0:
            break
        covered += n
        budget -= 1
    covered += min(budget, n_other)
    cov_cdc = min(covered, total) / total
    return {"coverage_2mr": cov_2mr, "coverage_cdc_2mr": cov_cdc,
            "extra_budget": extra_budget, "devices": total}
