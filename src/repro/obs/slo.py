"""SLO decomposition: TTFT/TPOT breakdowns and deadline-miss attribution.

Consumes the span trees built by ``obs.spans`` and answers, per request:

* where did the time go? (``queue_wait / prefill / decode / stall /
  fault_recovery`` sums that tile the request's latency),
* what made the first token late? (TTFT decomposition: initial queue
  wait + prefill + every fault_recovery episode and the decode work it
  discarded),
* did it miss its deadline, and WHY? — every miss (and every shed) is
  attributed to exactly one dominant cause from ``CAUSES``.

Aggregates (``summarize``) yield p50/p99 TTFT/TPOT with per-phase
breakdown percentiles plus miss/shed-by-cause counts; these feed
``repro_slo_*`` Prometheus series, per-arch BENCH rows (the chaos bench's
fault-attributed p99 inflation headline), and the CLI::

    python -m repro.obs.slo report --trace results/chaos.trace.json

which re-renders the same tables from a Perfetto trace file — the
exporter embeds each request's decomposition in its root span close
event, so the trace is self-contained.

Everything here is arithmetic over SimClock stamps: deterministic,
replay-stable, and covered by the history gate (``ttft_p99_ms`` /
``tpot_p50_ms`` are gated lower-is-better metrics).
"""
from __future__ import annotations

import argparse
import json
import sys

from .spans import (
    SPAN_DECODE, SPAN_FAULT_RECOVERY, SPAN_PREFILL, SPAN_QUEUE_WAIT,
    SPAN_STALL, RequestTree, SpanTracker,
)

#: the closed set of deadline-miss / shed causes. Attribution picks the
#: phase with the largest time share; ties break in this (priority) order.
CAUSES = ("queue_wait", "prefill", "straggler", "fault_recovery", "shed")

#: shed reasons stamped by the admission queue
SHED_REASONS = ("queue_full", "displaced")


def _pct(xs, q: float) -> float:
    """Nearest-rank percentile (same convention as runtime.metrics)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return float(s[k])


def decompose(tree: RequestTree) -> dict:
    """Per-request decomposition dict from one TERMINAL span tree.

    Phase sums tile ``latency_ms`` (queue_wait + prefill + decode +
    fault_recovery); ``stall_ms`` is carved out of decode — it is the
    deterministic straggler/fault excess inside kept decode rounds, not
    an extra phase. ``ttft_decomp`` tiles ``ttft_ms``: the first token
    arrives at the END of the last fault_recovery episode (prefill
    re-issues it), so TTFT = initial queue_wait + all wasted decode +
    all fault_recovery + (sim-instant) prefill.
    """
    if tree.state == "open":
        raise ValueError(f"request {tree.rid}: cannot decompose an open tree")
    phases = tree.phases()

    def total(name):
        return sum(p.dur_ms for p in phases if p.name == name)

    queue_wait = phases[0].dur_ms if phases and \
        phases[0].name == SPAN_QUEUE_WAIT else 0.0
    prefill = total(SPAN_PREFILL)
    fault_recovery = total(SPAN_FAULT_RECOVERY)
    wasted_decode = sum(p.dur_ms for p in phases
                        if p.name == SPAN_DECODE and p.args.get("wasted"))
    kept_decode = total(SPAN_DECODE) - wasted_decode
    # stall inside KEPT rounds only: wasted rounds are already charged to
    # fault_recovery wholesale, so their stalls must not also count as
    # straggler time (the attribution shares stay disjoint)
    stall = sum(s.dur_ms for p in phases
                if p.name == SPAN_DECODE and not p.args.get("wasted")
                for s in p.walk() if s.name == SPAN_STALL)

    latency = (tree.finished_ms - tree.arrival_ms) \
        if tree.finished_ms is not None else 0.0
    n_tokens = int(tree.root.args.get("n_tokens", 0))
    ttft = tree.root.args.get("ttft_ms")
    if ttft is None:  # shed before any token
        ttft = latency
    # decode time per generated token after the first
    tpot = (kept_decode / (n_tokens - 1)) if n_tokens > 1 else 0.0

    deadline = tree.deadline_ms
    missed = bool(tree.state == "shed" or
                  (deadline is not None and tree.finished_ms is not None
                   and tree.finished_ms > deadline))

    row = {
        "rid": tree.rid,
        "state": tree.state,
        "latency_ms": latency,
        "ttft_ms": float(ttft),
        "tpot_ms": tpot,
        "n_tokens": n_tokens,
        "n_requeues": int(tree.root.args.get("n_requeues", 0)),
        "queue_wait_ms": queue_wait,
        "prefill_ms": prefill,
        "decode_ms": kept_decode,
        "stall_ms": stall,
        "fault_recovery_ms": fault_recovery + wasted_decode,
        "ttft_decomp": {
            "queue_wait": queue_wait,
            "prefill": prefill,
            "fault_recovery": fault_recovery + wasted_decode,
        },
        "missed": missed,
        "shed_reason": tree.root.args.get("shed_reason"),
    }
    row["cause"] = attribute(row) if missed else None
    return row


def attribute(row: dict) -> str:
    """Dominant-cause attribution for one missed/shed request — exactly
    one cause from ``CAUSES``. Sheds are attributed to ``shed``
    unconditionally (the depth bound, not a phase, killed the request);
    otherwise the largest contributor wins, ties broken by ``CAUSES``
    order (earlier pipeline stages take precedence: a request that spent
    equal time queued and stalled missed because admission was late)."""
    if row["state"] == "shed":
        return "shed"
    shares = {
        "queue_wait": row["queue_wait_ms"],
        "prefill": row["prefill_ms"],
        "straggler": row["stall_ms"],
        "fault_recovery": row["fault_recovery_ms"],
    }
    best = max(shares.values())
    for cause in CAUSES:
        if cause in shares and shares[cause] >= best - 1e-9:
            return cause
    return "queue_wait"  # unreachable: shares is non-empty


def decompositions(tracker: SpanTracker) -> list[dict]:
    """Decompose every terminal tree (rid-ordered)."""
    return [decompose(t) for t in tracker.terminal()]


def summarize(rows_or_tracker) -> dict:
    """Aggregate decomposition rows into the SLO summary block used by
    the benchmarks, the Prometheus exporter, and the CLI tables."""
    rows = rows_or_tracker
    if isinstance(rows_or_tracker, SpanTracker):
        rows = decompositions(rows_or_tracker)
    rows = list(rows)
    done = [r for r in rows if r["state"] == "completed"]
    ttft = [r["ttft_ms"] for r in done]
    tpot = [r["tpot_ms"] for r in done if r["n_tokens"] > 1]
    miss_by_cause = {c: 0 for c in CAUSES}
    shed_by_reason = {s: 0 for s in SHED_REASONS}
    for r in rows:
        if r["missed"]:
            miss_by_cause[r["cause"]] += 1
        if r["state"] == "shed" and r.get("shed_reason"):
            shed_by_reason.setdefault(r["shed_reason"], 0)
            shed_by_reason[r["shed_reason"]] += 1

    def phase_pcts(key):
        vals = [r[key] for r in done]
        return {"p50_ms": _pct(vals, 50), "p99_ms": _pct(vals, 99)}

    return {
        "n_requests": len(rows),
        "n_completed": len(done),
        "n_shed": sum(1 for r in rows if r["state"] == "shed"),
        "n_missed": sum(1 for r in rows if r["missed"]),
        "ttft_p50_ms": _pct(ttft, 50),
        "ttft_p99_ms": _pct(ttft, 99),
        "tpot_p50_ms": _pct(tpot, 50),
        "tpot_p99_ms": _pct(tpot, 99),
        "decomp": {
            "queue_wait": phase_pcts("queue_wait_ms"),
            "prefill": phase_pcts("prefill_ms"),
            "decode": phase_pcts("decode_ms"),
            "stall": phase_pcts("stall_ms"),
            "fault_recovery": phase_pcts("fault_recovery_ms"),
        },
        "miss_by_cause": miss_by_cause,
        "shed_by_reason": shed_by_reason,
    }


def prometheus_lines(summary: dict) -> list[str]:
    """``repro_slo_*`` Prometheus exposition lines from a summary."""
    out = [
        "# HELP repro_slo_ttft_ms Time-to-first-token percentiles (sim ms).",
        "# TYPE repro_slo_ttft_ms gauge",
        f'repro_slo_ttft_ms{{quantile="0.5"}} {summary["ttft_p50_ms"]}',
        f'repro_slo_ttft_ms{{quantile="0.99"}} {summary["ttft_p99_ms"]}',
        "# HELP repro_slo_tpot_ms Time-per-output-token percentiles (sim ms).",
        "# TYPE repro_slo_tpot_ms gauge",
        f'repro_slo_tpot_ms{{quantile="0.5"}} {summary["tpot_p50_ms"]}',
        f'repro_slo_tpot_ms{{quantile="0.99"}} {summary["tpot_p99_ms"]}',
        "# HELP repro_slo_deadline_miss_total Deadline misses by dominant cause.",
        "# TYPE repro_slo_deadline_miss_total counter",
    ]
    for cause in CAUSES:
        out.append(f'repro_slo_deadline_miss_total{{cause="{cause}"}} '
                   f'{summary["miss_by_cause"].get(cause, 0)}')
    out += [
        "# HELP repro_slo_shed_total Requests shed by the admission queue, by reason.",
        "# TYPE repro_slo_shed_total counter",
    ]
    for reason in sorted(set(SHED_REASONS) | set(summary["shed_by_reason"])):
        out.append(f'repro_slo_shed_total{{reason="{reason}"}} '
                   f'{summary["shed_by_reason"].get(reason, 0)}')
    for phase in ("queue_wait", "prefill", "decode", "stall",
                  "fault_recovery"):
        p = summary["decomp"][phase]
        out += [
            f'repro_slo_phase_ms{{phase="{phase}",quantile="0.5"}} '
            f'{p["p50_ms"]}',
            f'repro_slo_phase_ms{{phase="{phase}",quantile="0.99"}} '
            f'{p["p99_ms"]}',
        ]
    return out


# ---------------------------------------------------------------- CLI ----

def rows_from_trace(trace: dict) -> list[dict]:
    """Recover per-request decomposition rows from a Perfetto trace file:
    the exporter embeds each row in the root span's async-end event."""
    rows = []
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") == "e" and ev.get("name") == "request":
            decomp = (ev.get("args") or {}).get("slo")
            if decomp is not None:
                rows.append(decomp)
    return sorted(rows, key=lambda r: r["rid"])


def _fmt_table(headers, rows) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    line = "  ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    sep = "  ".join("-" * w for w in widths)
    body = "\n".join("  ".join(str(c).ljust(w) for c, w in zip(r, widths))
                     for r in rows)
    return "\n".join([line, sep, body] if rows else [line, sep])


def render_report(rows: list[dict]) -> str:
    """Human-readable SLO report (the ``report`` subcommand's output)."""
    s = summarize(rows)
    ms = lambda v: f"{v:.2f}"
    out = [
        f"requests: {s['n_requests']}  completed: {s['n_completed']}  "
        f"shed: {s['n_shed']}  deadline-missed: {s['n_missed']}",
        "",
        "latency percentiles (sim ms)",
        _fmt_table(
            ["metric", "p50", "p99"],
            [["ttft_ms", ms(s["ttft_p50_ms"]), ms(s["ttft_p99_ms"])],
             ["tpot_ms", ms(s["tpot_p50_ms"]), ms(s["tpot_p99_ms"])]]),
        "",
        "per-phase decomposition (sim ms, completed requests)",
        _fmt_table(
            ["phase", "p50", "p99"],
            [[ph, ms(s["decomp"][ph]["p50_ms"]), ms(s["decomp"][ph]["p99_ms"])]
             for ph in ("queue_wait", "prefill", "decode", "stall",
                        "fault_recovery")]),
    ]
    if s["n_missed"]:
        out += ["", "deadline misses by dominant cause",
                _fmt_table(["cause", "count"],
                           [[c, n] for c, n in s["miss_by_cause"].items()
                            if n])]
    if s["n_shed"]:
        out += ["", "sheds by reason",
                _fmt_table(["reason", "count"],
                           [[c, n] for c, n in s["shed_by_reason"].items()
                            if n])]
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.slo",
        description="Render SLO breakdown tables from a Perfetto trace "
                    "produced by repro.launch.serve --trace.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report", help="p50/p99 TTFT/TPOT decomposition "
                                        "and miss attribution tables")
    rep.add_argument("--trace", required=True,
                     help="chrome trace JSON written by --trace")
    rep.add_argument("--json", action="store_true",
                     help="emit the summary as JSON instead of tables")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        trace = json.load(f)
    rows = rows_from_trace(trace)
    if not rows:
        print("no request spans with slo decompositions found in "
              f"{args.trace}", file=sys.stderr)
        return 2
    if args.json:
        print(json.dumps(summarize(rows), indent=2, sort_keys=True))
    else:
        print(render_report(rows))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
