"""Exporters for the flight recorder: Perfetto/Chrome trace JSON and
Prometheus text-format metrics, plus a tiny live exposition server.

Chrome ``trace_event`` format (loadable at https://ui.perfetto.dev or
chrome://tracing): one process ("repro.runtime"), one thread per track —
``requests``, ``rounds``, ``planner``, one per decode slot
(``slot:<i>``), one per coded shard (``shard:<i>``). Timestamps are the
runtime's SIMULATED clock in microseconds (deterministic, so a replayed
chaos run exports a byte-identical trace modulo wall fields); the wall
stamps ride along in each event's ``args`` under ``wall_*`` keys.
``ShardTimeline`` down-intervals render as red-able "down" slices on the
shard tracks, so per-shard unavailability is visible at a glance.

``validate_chrome_trace`` is the schema + causality checker CI runs on
every traced chaos artifact: structural validity (required keys, known
phases, non-negative spans) and the paper's recovery claim as a trace
property — EVERY ``fault.inject`` erasure must be resolved by a matching
``fault.recovered`` (in-step CDC), a ``fault.beyond_budget`` followed by
the ``shard.heal_all`` + ``code.reencode`` 2MR chain, or an explicit
``fault.noop`` (duplicate report of an already-dead shard).

``prometheus_text`` renders ``RuntimeMetrics`` (counters -> ``_total``
counters, bounded histograms -> ``_bucket/_sum/_count`` series) plus
per-shard duty-cycle gauges; ``MetricsServer`` serves it at
``/metrics`` (and the live trace at ``/trace``) from a daemon thread —
``launch/serve.py --metrics-port`` wires it up.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any

from repro.obs.tracer import FlightRecorder

_PROCESS = "repro.runtime"
_KNOWN_PHASES = {"X", "i", "I", "M", "b", "e", "n", "s", "t", "f", "C"}


# ---------------------------------------------------------- chrome trace ----

def _track_order(tracks: list[str]) -> list[str]:
    """Stable display order: requests, spans, rounds, planner, perf,
    slots, shards."""
    def key(t: str):
        head, _, idx = t.partition(":")
        fixed = {"requests": 0, "spans": 1, "rounds": 2, "planner": 3,
                 "perf": 4, "slot": 5, "shard": 6}
        return (fixed.get(head, 7), int(idx) if idx.isdigit() else 0, t)
    return sorted(set(tracks), key=key)


def chrome_trace(recorder: FlightRecorder, shardlog=None,
                 now_ms: float | None = None,
                 meta: dict | None = None, spans=None) -> dict:
    """Serialise the recorder (and optional shard timeline and
    ``SpanTracker``) as a Chrome ``trace_event`` JSON object. Terminal
    request span trees render as async b/e events on a dedicated
    ``spans`` track, with flow arrows ("s"/"f" pairs) from each round's
    dispatch event to the decode slices that rode it and from each
    injected fault's position to the ``fault_recovery`` span it caused;
    each root span's end event embeds the ``obs.slo`` decomposition, so
    the trace file is a self-contained SLO report."""
    events = recorder.events()
    tracks = [e.track for e in events]
    if shardlog is not None:
        tracks += [f"shard:{i}" for i in range(shardlog.n_shards)]
    if spans is not None and len(spans.done):
        tracks += ["spans", "rounds"]
    order = _track_order(tracks)
    tid = {t: i + 1 for i, t in enumerate(order)}

    out: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
        "args": {"name": _PROCESS},
    }]
    for t in order:
        out.append({"name": "thread_name", "ph": "M", "pid": 1,
                    "tid": tid[t], "args": {"name": t}})
        out.append({"name": "thread_sort_index", "ph": "M", "pid": 1,
                    "tid": tid[t], "args": {"sort_index": tid[t]}})

    for e in events:
        args = dict(e.args)
        args["wall_ms"] = e.wall_ms
        if e.wall_dur_ms:
            args["wall_dur_ms"] = e.wall_dur_ms
        for k, v in e.wall_args.items():
            args[f"wall_{k}"] = v
        rec = {
            "name": e.kind,
            "cat": e.kind.split(".", 1)[0],
            "pid": 1,
            "tid": tid[e.track],
            "ts": e.t_ms * 1e3,          # trace_event wants microseconds
            "args": args,
        }
        if e.kind == "perf.counter":
            # Perfetto counter sample: every numeric arg becomes a series
            # on the perf track (strings would chart as garbage)
            rec["ph"] = "C"
            rec["args"] = {k: v for k, v in args.items()
                           if isinstance(v, (int, float))
                           and not isinstance(v, bool)}
        elif e.dur_ms > 0:
            rec["ph"], rec["dur"] = "X", e.dur_ms * 1e3
        else:
            rec["ph"], rec["s"] = "i", "t"
        out.append(rec)

    if shardlog is not None:
        for shard, t0, t1, cause in shardlog.all_intervals(now_ms):
            out.append({
                "name": "down", "cat": "health", "ph": "X", "pid": 1,
                "tid": tid[f"shard:{shard}"], "ts": t0 * 1e3,
                "dur": max(t1 - t0, 0.0) * 1e3,
                "args": {"shard": shard, "healed_by": cause},
            })

    if spans is not None and len(spans.done):
        _emit_span_events(out, spans, tid, events)

    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "otherData": {
            "exporter": "repro.obs",
            "clock": "simulated-ms (wall stamps in args.wall_*)",
            "n_events": len(events),
            "dropped_events": recorder.dropped,
            **(meta or {}),
        },
    }


def _span_args(sp) -> dict:
    """Span args with the wall-clock fields folded in under ``wall_*``
    keys (same quarantine convention as ``TraceEvent`` export)."""
    args = dict(sp.args)
    args["wall_t0_ms"] = sp.wall_t0_ms
    for k, v in sp.wall_args.items():
        args[f"wall_{k}"] = v
    return args


def _emit_span_events(out: list, spans, tid: dict, events) -> None:
    """Render terminal request span trees as async b/e events plus the
    two flow-arrow families (round -> decode slice, injected fault ->
    fault_recovery span)."""
    from repro.obs.slo import decompose
    from repro.obs.spans import SPAN_FAULT_RECOVERY, SPAN_SLICE

    span_tid = tid["spans"]
    rounds_tid = tid.get("rounds", span_tid)
    # anchor lookup: dispatch id -> its round.dispatch event's sim ts
    round_ts = {e.args["round"]: e.t_ms * 1e3 for e in events
                if e.kind == "round.dispatch" and "round" in e.args}

    def emit(sp, rid):
        rec = {"name": sp.name, "cat": "span", "ph": "b", "pid": 1,
               "tid": span_tid, "id": str(rid), "ts": sp.t0_ms * 1e3,
               "args": _span_args(sp)}
        out.append(rec)
        if sp.name == SPAN_SLICE and "round" in sp.args:
            ridx = sp.args["round"]
            flow = {"name": "rode-round", "cat": "flow", "pid": 1,
                    "id": f"round{ridx}:rid{rid}"}
            out.append({**flow, "ph": "s", "tid": rounds_tid,
                        "ts": round_ts.get(ridx, sp.t0_ms * 1e3)})
            out.append({**flow, "ph": "f", "bp": "e", "tid": span_tid,
                        "ts": sp.t0_ms * 1e3})
        if sp.name == SPAN_FAULT_RECOVERY and "fault_t_ms" in sp.args:
            flow_id = (f"fault:s{sp.args.get('fault_shard', -1)}"
                       f"@{sp.args['fault_t_ms']}:rid{rid}")
            rec["args"]["flow_id"] = flow_id
            anchor = tid.get(f"shard:{sp.args.get('fault_shard')}",
                             rounds_tid)
            flow = {"name": "caused-requeue", "cat": "flow", "pid": 1,
                    "id": flow_id}
            out.append({**flow, "ph": "s", "tid": anchor,
                        "ts": sp.args["fault_t_ms"] * 1e3})
            out.append({**flow, "ph": "f", "bp": "e", "tid": span_tid,
                        "ts": sp.t0_ms * 1e3})
        for child in sp.children:
            emit(child, rid)
        end = {"name": sp.name, "cat": "span", "ph": "e", "pid": 1,
               "tid": span_tid, "id": str(rid), "ts": sp.t1_ms * 1e3,
               "args": {}}
        if sp.name == "request":
            # the trace is a self-contained SLO report: the CLI
            # (python -m repro.obs.slo report) reads these back
            end["args"]["slo"] = decompose(tree)
        out.append(end)

    for tree in spans.terminal():
        emit(tree.root, tree.rid)


def write_chrome_trace(path: str, recorder: FlightRecorder, shardlog=None,
                       now_ms: float | None = None,
                       meta: dict | None = None, spans=None) -> dict:
    trace = chrome_trace(recorder, shardlog, now_ms, meta, spans=spans)
    with open(path, "w") as f:
        json.dump(trace, f, indent=1, sort_keys=True)
    return trace


# ------------------------------------------------------------ validation ----

def validate_chrome_trace(trace: Any, require_fault_links: bool = False,
                          require_perf_counters: bool = False,
                          require_span_closure: bool = False) -> dict:
    """Structural + causal validation; raises ``ValueError`` on the first
    violation, returns summary stats otherwise. With
    ``require_fault_links=True`` the trace must contain at least one
    injected fault AND every injected erasure must be linked to its
    resolution (the CI chaos artifact contract). With
    ``require_perf_counters=True`` it must carry at least one counter
    ("C") sample on the ``perf`` track (the perf-observability contract
    for perf-enabled runs). With ``require_span_closure=True`` the trace
    must carry at least one request span tree and EVERY tree must satisfy
    the span contract — checked on any trace that has span events: every
    b has a matching e (same async id + name, properly nested), top-level
    phases tile the root gap-free, decode slices tile their decode span,
    every deadline miss carries exactly one attributed cause, and every
    ``fault_recovery`` span's flow arrow resolves to an s/f pair (0
    unlinked)."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a traceEvents list")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    names: dict[int, str] = {}
    n_counters = 0
    perf_counters = 0
    for i, e in enumerate(events):
        for key in ("name", "ph", "pid", "tid"):
            if key not in e:
                raise ValueError(f"event {i} missing {key!r}: {e}")
        if e["ph"] not in _KNOWN_PHASES:
            raise ValueError(f"event {i} has unknown phase {e['ph']!r}")
        if e["ph"] == "M":
            if e["name"] == "thread_name":
                names[e["tid"]] = e["args"]["name"]
            continue
        if "ts" not in e:
            raise ValueError(f"event {i} missing ts: {e}")
        if e["ts"] < 0:
            raise ValueError(f"event {i} has negative ts: {e}")
        if e["ph"] == "X" and e.get("dur", 0) < 0:
            raise ValueError(f"event {i} has negative dur: {e}")
        if e["tid"] not in names and e["tid"] != 0:
            raise ValueError(f"event {i} on unnamed track tid={e['tid']}")
        if e["ph"] == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args or any(
                    not isinstance(v, (int, float)) or isinstance(v, bool)
                    for v in args.values()):
                raise ValueError(f"counter event {i} must carry a "
                                 f"non-empty all-numeric args dict: {e}")
            n_counters += 1
            if names.get(e["tid"]) == "perf":
                perf_counters += 1

    injected = [e for e in events if e["name"] == "fault.inject"]
    erasures = [e for e in injected if e["args"].get("fault") == "erasure"]

    def _after(name: str, ts: float, shard: int | None = None):
        return [e for e in events
                if e["name"] == name and e["ts"] >= ts
                and (shard is None or e["args"].get("shard") == shard)]

    linked = 0
    for f in erasures:
        ts, shard = f["ts"], f["args"]["shard"]
        if _after("fault.recovered", ts, shard) or _after("fault.noop",
                                                          ts, shard):
            linked += 1
            continue
        beyond = _after("fault.beyond_budget", ts)
        if beyond and _after("shard.heal_all", beyond[0]["ts"]) \
                and _after("code.reencode", beyond[0]["ts"]):
            linked += 1
            continue
        raise ValueError(
            f"injected erasure on shard {shard} at ts={ts} has no "
            "recovery/requeue-heal-reencode/noop resolution in the trace")

    if require_fault_links and not erasures:
        raise ValueError("trace contains no injected erasures "
                         "(require_fault_links=True)")
    if require_perf_counters and perf_counters == 0:
        raise ValueError("trace carries no counter samples on the 'perf' "
                         "track (require_perf_counters=True)")
    span_stats = _validate_spans(events, require_span_closure)
    return {
        "n_events": sum(1 for e in events if e["ph"] != "M"),
        "n_tracks": len(names),
        "n_injected": len(injected),
        "n_injected_erasures": len(erasures),
        "n_linked": linked,
        "n_counters": n_counters,
        "n_perf_counters": perf_counters,
        "dropped_events": trace.get("otherData", {}).get("dropped_events",
                                                         0),
        **span_stats,
    }


#: tiling tolerance for span gap accounting, in trace_event µs
_SPAN_EPS_US = 0.5


def _validate_spans(events: list, require: bool) -> dict:
    """The span contract (see ``validate_chrome_trace``): applied to any
    trace carrying ``cat="span"`` async events; ``require=True``
    additionally demands that span trees exist at all."""
    from repro.obs.slo import CAUSES

    trees: dict[str, list] = {}          # async id -> root nodes
    stacks: dict[str, list] = {}
    flow_ids = {e["id"] for e in events
                if e.get("cat") == "flow" and e["ph"] in ("s", "t", "f")}
    flow_starts = {e["id"] for e in events
                   if e.get("cat") == "flow" and e["ph"] == "s"}
    flow_ends = {e["id"] for e in events
                 if e.get("cat") == "flow" and e["ph"] == "f"}
    n_fr = n_unlinked_fr = 0
    for i, e in enumerate(events):
        if e.get("cat") != "span":
            continue
        if "id" not in e:
            raise ValueError(f"span event {i} missing async id: {e}")
        sid = e["id"]
        if e["ph"] == "b":
            node = {"name": e["name"], "ts": e["ts"], "t1": None,
                    "args": e.get("args", {}), "children": []}
            stack = stacks.setdefault(sid, [])
            if stack:
                stack[-1]["children"].append(node)
            else:
                trees.setdefault(sid, []).append(node)
            stack.append(node)
            if e["name"] == "fault_recovery":
                n_fr += 1
                fid = node["args"].get("flow_id")
                if fid is None or fid not in flow_starts \
                        or fid not in flow_ends:
                    n_unlinked_fr += 1
        elif e["ph"] == "e":
            stack = stacks.get(sid)
            if not stack:
                raise ValueError(f"span end without open span (id={sid}, "
                                 f"name={e['name']})")
            node = stack.pop()
            if node["name"] != e["name"]:
                raise ValueError(
                    f"span nesting violation for id={sid}: closing "
                    f"{e['name']!r} but {node['name']!r} is open")
            if e["ts"] < node["ts"]:
                raise ValueError(f"span {e['name']!r} (id={sid}) closes "
                                 "before it opens")
            node["t1"] = e["ts"]
            node["end_args"] = e.get("args", {})

    for sid, stack in stacks.items():
        if stack:
            raise ValueError(
                f"unclosed span(s) for id={sid}: "
                f"{[n['name'] for n in stack]} (span contract requires "
                "every request tree closed)")

    n_missed = n_slices = n_roots = 0
    for sid, roots in trees.items():
        for root in roots:
            if root["name"] != "request":
                raise ValueError(f"top-level span {root['name']!r} "
                                 f"(id={sid}) is not a request root")
            n_roots += 1
            # gap accounting: phases tile the root, slices tile decode
            t = root["ts"]
            for ph in root["children"]:
                if abs(ph["ts"] - t) > _SPAN_EPS_US:
                    raise ValueError(
                        f"request {sid}: gap before {ph['name']!r} phase "
                        f"({t} -> {ph['ts']} us)")
                t = ph["t1"]
                if ph["name"] == "decode":
                    ts = ph["ts"]
                    for sl in ph["children"]:
                        if sl["name"] != "decode.round":
                            raise ValueError(
                                f"request {sid}: {sl['name']!r} directly "
                                "under decode")
                        if abs(sl["ts"] - ts) > _SPAN_EPS_US:
                            raise ValueError(
                                f"request {sid}: decode slice gap "
                                f"({ts} -> {sl['ts']} us)")
                        ts = sl["t1"]
                        n_slices += 1
                    if abs(ts - ph["t1"]) > _SPAN_EPS_US:
                        raise ValueError(
                            f"request {sid}: decode slices end at {ts}, "
                            f"span at {ph['t1']} us")
            if abs(t - root["t1"]) > _SPAN_EPS_US:
                raise ValueError(
                    f"request {sid}: phases end at {t}, root at "
                    f"{root['t1']} us (gap in the span tree)")
            slo = root.get("end_args", {}).get("slo")
            if slo is not None and slo.get("missed"):
                n_missed += 1
                cause = slo.get("cause")
                if cause not in CAUSES:
                    raise ValueError(
                        f"request {sid}: deadline miss with invalid "
                        f"cause {cause!r} (must be one of {CAUSES})")

    if require:
        if n_roots == 0:
            raise ValueError("trace carries no request span trees "
                             "(require_span_closure=True)")
        if n_unlinked_fr:
            raise ValueError(
                f"{n_unlinked_fr} fault_recovery span(s) lack a resolved "
                "flow arrow to their injector fault "
                "(require_span_closure=True)")
    return {
        "n_span_trees": n_roots,
        "n_span_slices": n_slices,
        "n_span_missed": n_missed,
        "n_fault_recovery_spans": n_fr,
        "n_unlinked_fault_recovery": n_unlinked_fr,
        "n_flow_ids": len(flow_ids),
    }


# ------------------------------------------------------------- prometheus ----

def _prom_hist(lines: list[str], name: str, hist, help_: str):
    lines.append(f"# HELP {name} {help_}")
    lines.append(f"# TYPE {name} histogram")
    cum = 0
    for le, count in hist.buckets():
        cum = count
        le_s = "+Inf" if le == float("inf") else f"{le:g}"
        lines.append(f'{name}_bucket{{le="{le_s}"}} {cum}')
    lines.append(f"{name}_sum {hist.total:g}")
    lines.append(f"{name}_count {hist.n}")


def prometheus_text(metrics, shardlog=None, now_ms: float | None = None,
                    recorder: FlightRecorder | None = None,
                    spans=None) -> str:
    """Render runtime metric state in the Prometheus text exposition
    format (0.0.4). ``metrics`` is a ``RuntimeMetrics``; the optional
    shard timeline adds per-shard duty-cycle gauges, the recorder adds
    trace-buffer meta-series, and a ``SpanTracker`` adds the
    ``repro_slo_*`` family (TTFT/TPOT percentiles, per-phase
    decomposition, deadline misses by dominant cause)."""
    lines: list[str] = []
    lines.append("# HELP repro_runtime_counter Runtime lifecycle counters.")
    lines.append("# TYPE repro_runtime_counter counter")
    for k in sorted(metrics.counters):
        lines.append(f'repro_runtime_counter{{name="{k}"}} '
                     f"{metrics.counters[k]}")
    lines.append("# HELP repro_requests_requeued_total Requests requeued "
                 "by the 2MR beyond-budget fallback.")
    lines.append("# TYPE repro_requests_requeued_total counter")
    lines.append("repro_requests_requeued_total "
                 f"{metrics.counters.get('requests_requeued', 0)}")
    lines.append("# HELP repro_requests_shed_total Requests shed by the "
                 "admission queue, by cause.")
    lines.append("# TYPE repro_requests_shed_total counter")
    shed_causes = getattr(metrics, "shed_causes", {}) or {}
    for cause in sorted(set(shed_causes) | {"queue_full", "displaced"}):
        lines.append(f'repro_requests_shed_total{{cause="{cause}"}} '
                     f"{shed_causes.get(cause, 0)}")
    for name, hist, help_ in (
            ("repro_request_latency_ms", metrics.latencies_ms,
             "Submit-to-last-token request latency (sim ms)."),
            ("repro_request_queueing_ms", metrics.queueing_ms,
             "Queueing delay before final admission (sim ms)."),
            ("repro_request_ttft_ms", metrics.ttft_ms,
             "Time to first token: arrival -> first generated token "
             "(sim ms)."),
            ("repro_round_measured_ms", metrics.round_ms,
             "MEASURED wall-clock decode-round latency (ms).")):
        _prom_hist(lines, name, hist, help_)
    lines.append("# HELP repro_queue_depth Admission queue depth.")
    lines.append("# TYPE repro_queue_depth gauge")
    lines.append(f"repro_queue_depth {metrics.queue_depth.last}")
    lines.append(f"repro_queue_depth_max {metrics.queue_depth.vmax}")
    if shardlog is not None:
        duty = shardlog.duty_cycle(now_ms)
        lines.append("# HELP repro_shard_unavailability Per-shard "
                     "unavailability duty cycle in [0, 1].")
        lines.append("# TYPE repro_shard_unavailability gauge")
        for i, u in enumerate(duty):
            lines.append(f'repro_shard_unavailability{{shard="{i}"}} '
                         f"{float(u):g}")
        lines.append("# HELP repro_shard_erasures_total Per-shard erasure "
                     "count.")
        lines.append("# TYPE repro_shard_erasures_total counter")
        for i in range(shardlog.n_shards):
            lines.append(f'repro_shard_erasures_total{{shard="{i}"}} '
                         f"{int(shardlog.erasures[i])}")
    perf = getattr(metrics, "perf", None)
    if perf:
        lines.append("# HELP repro_perf Roofline-anchored per-round cost "
                     "attribution and achieved rates (obs.perf).")
        lines.append("# TYPE repro_perf gauge")
        for k in sorted(perf):
            v = perf[k]
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            lines.append(f"repro_perf_{k} {float(v):g}")
    if recorder is not None:
        lines.append("# HELP repro_trace_events_total Events emitted to "
                     "the flight recorder.")
        lines.append("# TYPE repro_trace_events_total counter")
        lines.append(f"repro_trace_events_total {recorder.n_emitted}")
        lines.append(f"repro_trace_events_dropped_total {recorder.dropped}")
    if spans is not None and len(spans.done):
        from repro.obs.slo import prometheus_lines, summarize
        lines.extend(prometheus_lines(summarize(spans)))
    return "\n".join(lines) + "\n"


class MetricsServer:
    """Minimal live exposition server: ``/metrics`` (Prometheus text),
    ``/trace`` (current Chrome trace JSON) and ``/healthz`` (liveness
    probe), served from a daemon thread. ``port=0`` binds an ephemeral
    port (tests); read it back from ``server.port``."""

    def __init__(self, metrics, shardlog=None, recorder=None, clock=None,
                 port: int = 0, host: str = "127.0.0.1", spans=None):
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                              # noqa: N802
                if self.path.rstrip("/").endswith("healthz"):
                    body = b"ok\n"
                    ctype = "text/plain; charset=utf-8"
                elif self.path.rstrip("/") in ("", "/metrics", "metrics"):
                    body = outer.render_metrics().encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path.rstrip("/").endswith("trace"):
                    body = json.dumps(outer.render_trace()).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):                     # quiet
                pass

        self.metrics = metrics
        self.shardlog = shardlog
        self.recorder = recorder
        self.clock = clock
        self.spans = spans
        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread: threading.Thread | None = None

    def _now(self) -> float | None:
        return self.clock.now() if self.clock is not None else None

    def render_metrics(self) -> str:
        return prometheus_text(self.metrics, self.shardlog, self._now(),
                               self.recorder, spans=self.spans)

    def render_trace(self) -> dict:
        rec = self.recorder if self.recorder is not None \
            else FlightRecorder(capacity=1)
        return chrome_trace(rec, self.shardlog, self._now(),
                            spans=self.spans)

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
