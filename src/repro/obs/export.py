"""Exporters for the flight recorder: Perfetto/Chrome trace JSON and
Prometheus text-format metrics, plus a tiny live exposition server.

Chrome ``trace_event`` format (loadable at https://ui.perfetto.dev or
chrome://tracing): one process ("repro.runtime"), one thread per track —
``requests``, ``rounds``, ``planner``, one per decode slot
(``slot:<i>``), one per coded shard (``shard:<i>``). Timestamps are the
runtime's SIMULATED clock in microseconds (deterministic, so a replayed
chaos run exports a byte-identical trace modulo wall fields); the wall
stamps ride along in each event's ``args`` under ``wall_*`` keys.
``ShardTimeline`` down-intervals render as red-able "down" slices on the
shard tracks, so per-shard unavailability is visible at a glance.

``validate_chrome_trace`` is the schema + causality checker CI runs on
every traced chaos artifact: structural validity (required keys, known
phases, non-negative spans) and the paper's recovery claim as a trace
property — EVERY ``fault.inject`` erasure must be resolved by a matching
``fault.recovered`` (in-step CDC), a ``fault.beyond_budget`` followed by
the ``shard.heal_all`` + ``code.reencode`` 2MR chain, or an explicit
``fault.noop`` (duplicate report of an already-dead shard).

``prometheus_text`` renders ``RuntimeMetrics`` (counters -> ``_total``
counters, bounded histograms -> ``_bucket/_sum/_count`` series) plus
per-shard duty-cycle gauges; ``MetricsServer`` serves it at
``/metrics`` (and the live trace at ``/trace``) from a daemon thread —
``launch/serve.py --metrics-port`` wires it up.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any

from repro.obs.tracer import FlightRecorder

_PROCESS = "repro.runtime"
_KNOWN_PHASES = {"X", "i", "I", "M", "b", "e", "n", "s", "t", "f", "C"}


# ---------------------------------------------------------- chrome trace ----

def _track_order(tracks: list[str]) -> list[str]:
    """Stable display order: requests, rounds, planner, perf, slots,
    shards."""
    def key(t: str):
        head, _, idx = t.partition(":")
        fixed = {"requests": 0, "rounds": 1, "planner": 2, "perf": 3,
                 "slot": 4, "shard": 5}
        return (fixed.get(head, 6), int(idx) if idx.isdigit() else 0, t)
    return sorted(set(tracks), key=key)


def chrome_trace(recorder: FlightRecorder, shardlog=None,
                 now_ms: float | None = None,
                 meta: dict | None = None) -> dict:
    """Serialise the recorder (and optional shard timeline) as a Chrome
    ``trace_event`` JSON object."""
    events = recorder.events()
    tracks = [e.track for e in events]
    if shardlog is not None:
        tracks += [f"shard:{i}" for i in range(shardlog.n_shards)]
    order = _track_order(tracks)
    tid = {t: i + 1 for i, t in enumerate(order)}

    out: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": 1, "tid": 0,
        "args": {"name": _PROCESS},
    }]
    for t in order:
        out.append({"name": "thread_name", "ph": "M", "pid": 1,
                    "tid": tid[t], "args": {"name": t}})
        out.append({"name": "thread_sort_index", "ph": "M", "pid": 1,
                    "tid": tid[t], "args": {"sort_index": tid[t]}})

    for e in events:
        args = dict(e.args)
        args["wall_ms"] = e.wall_ms
        if e.wall_dur_ms:
            args["wall_dur_ms"] = e.wall_dur_ms
        for k, v in e.wall_args.items():
            args[f"wall_{k}"] = v
        rec = {
            "name": e.kind,
            "cat": e.kind.split(".", 1)[0],
            "pid": 1,
            "tid": tid[e.track],
            "ts": e.t_ms * 1e3,          # trace_event wants microseconds
            "args": args,
        }
        if e.kind == "perf.counter":
            # Perfetto counter sample: every numeric arg becomes a series
            # on the perf track (strings would chart as garbage)
            rec["ph"] = "C"
            rec["args"] = {k: v for k, v in args.items()
                           if isinstance(v, (int, float))
                           and not isinstance(v, bool)}
        elif e.dur_ms > 0:
            rec["ph"], rec["dur"] = "X", e.dur_ms * 1e3
        else:
            rec["ph"], rec["s"] = "i", "t"
        out.append(rec)

    if shardlog is not None:
        for shard, t0, t1, cause in shardlog.all_intervals(now_ms):
            out.append({
                "name": "down", "cat": "health", "ph": "X", "pid": 1,
                "tid": tid[f"shard:{shard}"], "ts": t0 * 1e3,
                "dur": max(t1 - t0, 0.0) * 1e3,
                "args": {"shard": shard, "healed_by": cause},
            })

    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "otherData": {
            "exporter": "repro.obs",
            "clock": "simulated-ms (wall stamps in args.wall_*)",
            "n_events": len(events),
            "dropped_events": recorder.dropped,
            **(meta or {}),
        },
    }


def write_chrome_trace(path: str, recorder: FlightRecorder, shardlog=None,
                       now_ms: float | None = None,
                       meta: dict | None = None) -> dict:
    trace = chrome_trace(recorder, shardlog, now_ms, meta)
    with open(path, "w") as f:
        json.dump(trace, f, indent=1, sort_keys=True)
    return trace


# ------------------------------------------------------------ validation ----

def validate_chrome_trace(trace: Any, require_fault_links: bool = False,
                          require_perf_counters: bool = False) -> dict:
    """Structural + causal validation; raises ``ValueError`` on the first
    violation, returns summary stats otherwise. With
    ``require_fault_links=True`` the trace must contain at least one
    injected fault AND every injected erasure must be linked to its
    resolution (the CI chaos artifact contract). With
    ``require_perf_counters=True`` it must carry at least one counter
    ("C") sample on the ``perf`` track (the perf-observability contract
    for perf-enabled runs)."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace must be a dict with a traceEvents list")
    events = trace["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    names: dict[int, str] = {}
    n_counters = 0
    perf_counters = 0
    for i, e in enumerate(events):
        for key in ("name", "ph", "pid", "tid"):
            if key not in e:
                raise ValueError(f"event {i} missing {key!r}: {e}")
        if e["ph"] not in _KNOWN_PHASES:
            raise ValueError(f"event {i} has unknown phase {e['ph']!r}")
        if e["ph"] == "M":
            if e["name"] == "thread_name":
                names[e["tid"]] = e["args"]["name"]
            continue
        if "ts" not in e:
            raise ValueError(f"event {i} missing ts: {e}")
        if e["ts"] < 0:
            raise ValueError(f"event {i} has negative ts: {e}")
        if e["ph"] == "X" and e.get("dur", 0) < 0:
            raise ValueError(f"event {i} has negative dur: {e}")
        if e["tid"] not in names and e["tid"] != 0:
            raise ValueError(f"event {i} on unnamed track tid={e['tid']}")
        if e["ph"] == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args or any(
                    not isinstance(v, (int, float)) or isinstance(v, bool)
                    for v in args.values()):
                raise ValueError(f"counter event {i} must carry a "
                                 f"non-empty all-numeric args dict: {e}")
            n_counters += 1
            if names.get(e["tid"]) == "perf":
                perf_counters += 1

    injected = [e for e in events if e["name"] == "fault.inject"]
    erasures = [e for e in injected if e["args"].get("fault") == "erasure"]

    def _after(name: str, ts: float, shard: int | None = None):
        return [e for e in events
                if e["name"] == name and e["ts"] >= ts
                and (shard is None or e["args"].get("shard") == shard)]

    linked = 0
    for f in erasures:
        ts, shard = f["ts"], f["args"]["shard"]
        if _after("fault.recovered", ts, shard) or _after("fault.noop",
                                                          ts, shard):
            linked += 1
            continue
        beyond = _after("fault.beyond_budget", ts)
        if beyond and _after("shard.heal_all", beyond[0]["ts"]) \
                and _after("code.reencode", beyond[0]["ts"]):
            linked += 1
            continue
        raise ValueError(
            f"injected erasure on shard {shard} at ts={ts} has no "
            "recovery/requeue-heal-reencode/noop resolution in the trace")

    if require_fault_links and not erasures:
        raise ValueError("trace contains no injected erasures "
                         "(require_fault_links=True)")
    if require_perf_counters and perf_counters == 0:
        raise ValueError("trace carries no counter samples on the 'perf' "
                         "track (require_perf_counters=True)")
    return {
        "n_events": sum(1 for e in events if e["ph"] != "M"),
        "n_tracks": len(names),
        "n_injected": len(injected),
        "n_injected_erasures": len(erasures),
        "n_linked": linked,
        "n_counters": n_counters,
        "n_perf_counters": perf_counters,
        "dropped_events": trace.get("otherData", {}).get("dropped_events",
                                                         0),
    }


# ------------------------------------------------------------- prometheus ----

def _prom_hist(lines: list[str], name: str, hist, help_: str):
    lines.append(f"# HELP {name} {help_}")
    lines.append(f"# TYPE {name} histogram")
    cum = 0
    for le, count in hist.buckets():
        cum = count
        le_s = "+Inf" if le == float("inf") else f"{le:g}"
        lines.append(f'{name}_bucket{{le="{le_s}"}} {cum}')
    lines.append(f"{name}_sum {hist.total:g}")
    lines.append(f"{name}_count {hist.n}")


def prometheus_text(metrics, shardlog=None, now_ms: float | None = None,
                    recorder: FlightRecorder | None = None) -> str:
    """Render runtime metric state in the Prometheus text exposition
    format (0.0.4). ``metrics`` is a ``RuntimeMetrics``; the optional
    shard timeline adds per-shard duty-cycle gauges and the recorder
    adds trace-buffer meta-series."""
    lines: list[str] = []
    lines.append("# HELP repro_runtime_counter Runtime lifecycle counters.")
    lines.append("# TYPE repro_runtime_counter counter")
    for k in sorted(metrics.counters):
        lines.append(f'repro_runtime_counter{{name="{k}"}} '
                     f"{metrics.counters[k]}")
    for name, hist, help_ in (
            ("repro_request_latency_ms", metrics.latencies_ms,
             "Submit-to-last-token request latency (sim ms)."),
            ("repro_request_queueing_ms", metrics.queueing_ms,
             "Queueing delay before final admission (sim ms)."),
            ("repro_request_ttft_ms", metrics.ttft_ms,
             "Time to first token: arrival -> first generated token "
             "(sim ms)."),
            ("repro_round_measured_ms", metrics.round_ms,
             "MEASURED wall-clock decode-round latency (ms).")):
        _prom_hist(lines, name, hist, help_)
    lines.append("# HELP repro_queue_depth Admission queue depth.")
    lines.append("# TYPE repro_queue_depth gauge")
    lines.append(f"repro_queue_depth {metrics.queue_depth.last}")
    lines.append(f"repro_queue_depth_max {metrics.queue_depth.vmax}")
    if shardlog is not None:
        duty = shardlog.duty_cycle(now_ms)
        lines.append("# HELP repro_shard_unavailability Per-shard "
                     "unavailability duty cycle in [0, 1].")
        lines.append("# TYPE repro_shard_unavailability gauge")
        for i, u in enumerate(duty):
            lines.append(f'repro_shard_unavailability{{shard="{i}"}} '
                         f"{float(u):g}")
        lines.append("# HELP repro_shard_erasures_total Per-shard erasure "
                     "count.")
        lines.append("# TYPE repro_shard_erasures_total counter")
        for i in range(shardlog.n_shards):
            lines.append(f'repro_shard_erasures_total{{shard="{i}"}} '
                         f"{int(shardlog.erasures[i])}")
    perf = getattr(metrics, "perf", None)
    if perf:
        lines.append("# HELP repro_perf Roofline-anchored per-round cost "
                     "attribution and achieved rates (obs.perf).")
        lines.append("# TYPE repro_perf gauge")
        for k in sorted(perf):
            v = perf[k]
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            lines.append(f"repro_perf_{k} {float(v):g}")
    if recorder is not None:
        lines.append("# HELP repro_trace_events_total Events emitted to "
                     "the flight recorder.")
        lines.append("# TYPE repro_trace_events_total counter")
        lines.append(f"repro_trace_events_total {recorder.n_emitted}")
        lines.append(f"repro_trace_events_dropped_total {recorder.dropped}")
    return "\n".join(lines) + "\n"


class MetricsServer:
    """Minimal live exposition server: ``/metrics`` (Prometheus text),
    ``/trace`` (current Chrome trace JSON) and ``/healthz`` (liveness
    probe), served from a daemon thread. ``port=0`` binds an ephemeral
    port (tests); read it back from ``server.port``."""

    def __init__(self, metrics, shardlog=None, recorder=None, clock=None,
                 port: int = 0, host: str = "127.0.0.1"):
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                              # noqa: N802
                if self.path.rstrip("/").endswith("healthz"):
                    body = b"ok\n"
                    ctype = "text/plain; charset=utf-8"
                elif self.path.rstrip("/") in ("", "/metrics", "metrics"):
                    body = outer.render_metrics().encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path.rstrip("/").endswith("trace"):
                    body = json.dumps(outer.render_trace()).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):                     # quiet
                pass

        self.metrics = metrics
        self.shardlog = shardlog
        self.recorder = recorder
        self.clock = clock
        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread: threading.Thread | None = None

    def _now(self) -> float | None:
        return self.clock.now() if self.clock is not None else None

    def render_metrics(self) -> str:
        return prometheus_text(self.metrics, self.shardlog, self._now(),
                               self.recorder)

    def render_trace(self) -> dict:
        rec = self.recorder if self.recorder is not None \
            else FlightRecorder(capacity=1)
        return chrome_trace(rec, self.shardlog, self._now())

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
