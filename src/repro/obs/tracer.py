"""Flight recorder: structured, dual-stamped event tracing for the runtime.

The runtime's telemetry so far was end-of-run aggregates
(``RuntimeMetrics``): 13 counters and latency distributions, with no way
to see WHICH shard erased in WHICH round, how long a device stayed
unhealthy, or what the planner saw when it resized r. The flight
recorder fixes that: every lifecycle transition becomes a structured
``TraceEvent`` held in a bounded ring buffer, stamped with BOTH clocks —

  * ``t_ms``     — the runtime's simulated clock (deterministic: a seeded
    chaos run traced twice produces identical event streams);
  * ``wall_ms``  — process-relative wall time (real hardware timing; by
    construction the ONLY nondeterministic fields are ``wall_ms``,
    ``wall_dur_ms`` and ``wall_args``, so replay comparison is
    ``comparable()`` equality).

Event taxonomy (``kind``, dot-namespaced):

  request.submit / request.shed / request.admit / request.first_token /
  request.complete / request.requeue            — request lifecycle
  round.dispatch / round.harvest                — executor round lifecycle
     (harvest carries the overlap attribution: the pipelined round
      period and the device-block time NOT hidden by host work)
  fault.inject / fault.recovered / fault.beyond_budget / fault.noop      —
     injected fault -> its resolution (in-step CDC recovery, 2MR
     requeue, or duplicate report)
  shard.heal / shard.heal_all / code.reencode / code.resize             —
     heal + re-encode chain, planner-driven geometry changes
  planner.plan                                  — one planner decision with
     the window stats it saw (est unavailability, window max dead, reason)
  perf.attribution / perf.counter               — roofline cost attribution
     (once per code geometry) and the per-harvest achieved-vs-roofline
     counter samples (``obs.perf``; rendered as Perfetto counter tracks)

``track`` names the Perfetto track the event renders on: ``requests``,
``rounds``, ``planner``, ``perf``, ``slot:<i>``, ``shard:<i>``.

Disabled cost is one branch: call sites guard on ``tracer.enabled``
before building kwargs, and ``NULL_RECORDER`` (the default everywhere)
is a permanently-disabled singleton whose ``emit`` returns immediately —
a scheduler constructed without a tracer records zero events.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

#: the full event taxonomy; ``emit`` rejects unknown kinds so a typo
#: cannot create a phantom event stream (mirrors the counter registry).
EVENT_KINDS = frozenset({
    "request.submit", "request.shed", "request.admit",
    "request.first_token", "request.complete", "request.requeue",
    "round.dispatch", "round.harvest",
    "fault.inject", "fault.recovered", "fault.beyond_budget", "fault.noop",
    "shard.heal", "shard.heal_all", "code.reencode", "code.resize",
    "planner.plan",
    "perf.attribution", "perf.counter",
})


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One recorded event. ``dur_ms`` > 0 makes it a span (Perfetto "X"
    slice starting at ``t_ms``); 0 is an instant. Deterministic fields:
    everything except ``wall_ms``/``wall_dur_ms``/``wall_args``."""
    seq: int
    kind: str
    track: str
    t_ms: float                    # simulated clock stamp
    wall_ms: float                 # process-relative wall clock stamp
    dur_ms: float = 0.0            # span duration in sim time
    wall_dur_ms: float = 0.0       # span duration in wall time
    args: dict = dataclasses.field(default_factory=dict)
    wall_args: dict = dataclasses.field(default_factory=dict)

    def comparable(self) -> tuple:
        """The deterministic projection used by replay-equality tests."""
        return (self.seq, self.kind, self.track, self.t_ms, self.dur_ms,
                tuple(sorted(self.args.items())))


class FlightRecorder:
    """Bounded ring buffer of ``TraceEvent``s with dual-clock stamping.

    ``capacity`` bounds memory: once full, the OLDEST events are dropped
    (``dropped`` counts them) — the recorder never grows with run length.
    The simulated clock is bound lazily (``bind_clock``) by the first
    scheduler that uses the recorder, so ``emit`` callers without a clock
    in scope (e.g. ``ModelStepper.set_code_r``) still get sim stamps.
    """

    enabled: bool = True

    def __init__(self, capacity: int = 65536, clock: Any = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.buf: deque[TraceEvent] = deque(maxlen=self.capacity)
        self.clock = clock
        self.n_emitted = 0
        self._epoch = time.perf_counter()

    # ----------------------------------------------------------- clocks ----
    def bind_clock(self, clock: Any):
        """Adopt ``clock`` as the sim-time source if none is bound yet."""
        if self.clock is None:
            self.clock = clock

    def wall_now_ms(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e3

    # ------------------------------------------------------------ write ----
    def emit(self, kind: str, track: str = "runtime",
             t_ms: float | None = None, dur_ms: float = 0.0,
             wall_dur_ms: float = 0.0, wall_args: dict | None = None,
             **args) -> TraceEvent | None:
        """Record one event. ``t_ms=None`` stamps with the bound sim
        clock (0.0 if none). Keyword ``args`` must be JSON-serialisable
        and deterministic — wall-clock measurements go in ``wall_dur_ms``
        / ``wall_args`` so replay comparison stays exact."""
        if not self.enabled:
            return None
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown trace event kind {kind!r} "
                             f"(known: {sorted(EVENT_KINDS)})")
        if t_ms is None:
            t_ms = self.clock.now() if self.clock is not None else 0.0
        ev = TraceEvent(self.n_emitted, kind, track, float(t_ms),
                        self.wall_now_ms(), float(dur_ms),
                        float(wall_dur_ms), args, dict(wall_args or {}))
        self.n_emitted += 1
        self.buf.append(ev)
        return ev

    def clear(self):
        self.buf.clear()
        self.n_emitted = 0

    # ------------------------------------------------------------- read ----
    @property
    def dropped(self) -> int:
        """Events evicted by the ring bound since the last ``clear``."""
        return self.n_emitted - len(self.buf)

    def events(self) -> list[TraceEvent]:
        return list(self.buf)

    def by_kind(self, *kinds: str) -> list[TraceEvent]:
        want = set(kinds)
        return [e for e in self.buf if e.kind in want]

    def tracks(self) -> list[str]:
        seen: dict[str, None] = {}
        for e in self.buf:
            seen.setdefault(e.track)
        return list(seen)

    def comparable(self) -> list[tuple]:
        """Deterministic projection of the whole buffer (replay tests)."""
        return [e.comparable() for e in self.buf]

    def __len__(self) -> int:
        return len(self.buf)


class _NullRecorder(FlightRecorder):
    """Permanently disabled recorder: the default wired everywhere, so
    the un-traced hot path pays exactly one ``tracer.enabled`` branch."""

    enabled = False

    def __init__(self):
        super().__init__(capacity=1)

    def bind_clock(self, clock: Any):        # shared singleton: never bind
        pass

    def emit(self, *a, **kw) -> None:
        return None


NULL_RECORDER = _NullRecorder()
