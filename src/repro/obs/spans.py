"""Request-scoped span trees: per-request latency decomposition.

The flight recorder (PR 6) explains ROUNDS and the shard timeline
explains DEVICES, but neither answers the serving question the paper's
central claim is about: *why was request X slow, and was a fault the
cause?* This module builds one span tree per request, covering its whole
lifetime with NO gaps, so every millisecond of a request's latency is
attributed to exactly one phase:

    request (root: arrival -> terminal)
      queue_wait                       arrival -> first admission
      prefill                          prompt pass (sim-instant today;
                                       becomes a real span when chunked
                                       prefill lands — wall time is
                                       already measured and quarantined)
      decode                           one per admission episode
        decode.round                   one slice per decode round ridden,
                                       tagged with the executor round id
          stall                        the slice's straggler/fault excess
                                       over the fault-free counterfactual
                                       of the SAME latency draw
      fault_recovery                   a beyond-budget 2MR event evicted
                                       the request: requeue -> re-admission
        heal_wait                      replica swap + parity re-encode
                                       (sim-instant; wall cost quarantined)
        requeue                        time back in the admission queue

Top-level phases tile [arrival, terminal] exactly and decode slices tile
each decode span — ``RequestTree.check_closed`` enforces it, and the
Perfetto exporter re-checks the same contract on the serialised trace
(``validate_chrome_trace(require_span_closure=True)``).

Clock discipline matches ``TraceEvent``: the simulated clock is the
primary stamp (``t0_ms``/``t1_ms``), wall-clock measurements are
quarantined in ``wall_*`` fields, and ``comparable()`` projects them
away — a seeded chaos run traced twice yields bit-identical span trees.

``obs.slo`` consumes these trees: TTFT/TPOT decompositions, deadline-miss
cause attribution, Prometheus ``repro_slo_*`` counters, and the
``python -m repro.obs.slo report`` CLI.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from typing import Any

#: span taxonomy (tree levels documented in the module docstring)
SPAN_ROOT = "request"
SPAN_QUEUE_WAIT = "queue_wait"
SPAN_PREFILL = "prefill"
SPAN_DECODE = "decode"
SPAN_SLICE = "decode.round"
SPAN_STALL = "stall"
SPAN_FAULT_RECOVERY = "fault_recovery"
SPAN_HEAL_WAIT = "heal_wait"
SPAN_REQUEUE = "requeue"

SPAN_NAMES = frozenset({
    SPAN_ROOT, SPAN_QUEUE_WAIT, SPAN_PREFILL, SPAN_DECODE, SPAN_SLICE,
    SPAN_STALL, SPAN_FAULT_RECOVERY, SPAN_HEAL_WAIT, SPAN_REQUEUE,
})

#: top-level phases that must tile the root span (gap accounting)
TOP_PHASES = (SPAN_QUEUE_WAIT, SPAN_PREFILL, SPAN_DECODE,
              SPAN_FAULT_RECOVERY)

#: tolerance for the tiling checks (sim ms; float accumulation only)
GAP_EPS_MS = 1e-6


class Span:
    """One node of a request span tree.

    Deterministic fields: ``name``, ``t0_ms``, ``t1_ms``, ``args``,
    ``children``. Wall-clock measurements live ONLY in ``wall_t0_ms`` /
    ``wall_t1_ms`` / ``wall_args`` and are excluded from
    ``comparable()`` — the same quarantine ``TraceEvent`` applies.
    """

    __slots__ = ("name", "t0_ms", "t1_ms", "wall_t0_ms", "wall_t1_ms",
                 "args", "wall_args", "children")

    def __init__(self, name: str, t0_ms: float, wall_t0_ms: float = 0.0,
                 args: dict | None = None, wall_args: dict | None = None):
        if name not in SPAN_NAMES:
            raise ValueError(f"unknown span name {name!r} "
                             f"(known: {sorted(SPAN_NAMES)})")
        self.name = name
        self.t0_ms = float(t0_ms)
        self.t1_ms: float | None = None
        self.wall_t0_ms = float(wall_t0_ms)
        self.wall_t1_ms: float | None = None
        self.args: dict = dict(args or {})
        self.wall_args: dict = dict(wall_args or {})
        self.children: list[Span] = []

    # ----------------------------------------------------------- state ----
    @property
    def closed(self) -> bool:
        return self.t1_ms is not None

    @property
    def dur_ms(self) -> float:
        return (self.t1_ms - self.t0_ms) if self.closed else 0.0

    def close(self, t1_ms: float, wall_t1_ms: float | None = None):
        if self.closed:
            raise RuntimeError(f"span {self.name!r} already closed")
        if t1_ms < self.t0_ms:
            raise ValueError(f"span {self.name!r} would close before it "
                             f"opened ({t1_ms} < {self.t0_ms})")
        self.t1_ms = float(t1_ms)
        self.wall_t1_ms = float(wall_t1_ms) if wall_t1_ms is not None \
            else self.wall_t0_ms
        return self

    def add(self, child: "Span") -> "Span":
        self.children.append(child)
        return child

    # ------------------------------------------------------------ read ----
    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()

    def comparable(self) -> tuple:
        """Deterministic projection (replay-equality tests) — the same
        contract as ``TraceEvent.comparable``: no wall fields."""
        return (self.name, self.t0_ms, self.t1_ms,
                tuple(sorted(self.args.items())),
                tuple(c.comparable() for c in self.children))


class RequestTree:
    """The span tree of one request, built incrementally by the tracker
    as the scheduler drives the request through its lifecycle."""

    def __init__(self, rid: int, arrival_ms: float, wall_ms: float,
                 deadline_ms: float | None = None, priority: int = 0):
        self.rid = int(rid)
        self.deadline_ms = deadline_ms
        self.state = "open"               # open | completed | shed
        self.root = Span(SPAN_ROOT, arrival_ms, wall_ms,
                         args={"rid": self.rid, "deadline_ms": deadline_ms,
                               "priority": priority})
        self._wait: Span | None = None    # open queue_wait / fault_recovery
        self._decode: Span | None = None  # open decode episode

    # -------------------------------------------------------- accessors ----
    @property
    def arrival_ms(self) -> float:
        return self.root.t0_ms

    @property
    def finished_ms(self) -> float | None:
        return self.root.t1_ms

    def phases(self) -> list[Span]:
        return self.root.children

    def by_name(self, name: str) -> list[Span]:
        return [s for s in self.root.walk() if s.name == name]

    def comparable(self) -> tuple:
        return (self.rid, self.state, self.root.comparable())

    # ---------------------------------------------------------- contract ----
    def check_closed(self, eps: float = GAP_EPS_MS):
        """Raise ``ValueError`` unless this tree is terminal, every span is
        closed, top-level phases tile [arrival, terminal] gap-free, and
        decode slices tile their decode span. Returns self."""
        if self.state == "open":
            raise ValueError(f"request {self.rid}: tree still open")
        for s in self.root.walk():
            if not s.closed:
                raise ValueError(
                    f"request {self.rid}: span {s.name!r} never closed")
        t = self.root.t0_ms
        for phase in self.phases():
            if phase.name not in TOP_PHASES:
                raise ValueError(f"request {self.rid}: {phase.name!r} is "
                                 "not a top-level phase")
            if abs(phase.t0_ms - t) > eps:
                raise ValueError(
                    f"request {self.rid}: gap before {phase.name!r} "
                    f"({t} -> {phase.t0_ms})")
            t = phase.t1_ms
        if abs(t - self.root.t1_ms) > eps:
            raise ValueError(f"request {self.rid}: phases end at {t}, "
                             f"root at {self.root.t1_ms}")
        for dec in self.by_name(SPAN_DECODE):
            t = dec.t0_ms
            for sl in dec.children:
                if sl.name != SPAN_SLICE:
                    raise ValueError(f"request {self.rid}: {sl.name!r} "
                                     "under decode")
                if abs(sl.t0_ms - t) > eps:
                    raise ValueError(
                        f"request {self.rid}: decode slice gap "
                        f"({t} -> {sl.t0_ms})")
                t = sl.t1_ms
            if abs(t - dec.t1_ms) > eps:
                raise ValueError(
                    f"request {self.rid}: decode slices end at {t}, "
                    f"span at {dec.t1_ms}")
        return self


class SpanTracker:
    """Builds request span trees from runtime emission points.

    The scheduler owns one tracker (always on, like ``ShardTimeline``) and
    drives it from submission/admission/round/requeue/terminal hooks; the
    admission queue stamps shed reasons, the executor pool attaches
    measured per-round wall attribution, and ``ModelStepper`` supplies
    prefill / re-encode wall costs. Memory is bounded: terminal trees
    live in a ring (oldest dropped, counted), per-round wall buffers in a
    small deque.
    """

    def __init__(self, capacity: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.open: dict[int, RequestTree] = {}
        self.done: deque[RequestTree] = deque(maxlen=self.capacity)
        self.n_terminal = 0
        self._epoch = time.perf_counter()
        # measured wall attribution arrives from the executor pool a round
        # late (overlap) or a round early (sync harvest): buffer both ways
        self._slices_by_round: OrderedDict[int, list[Span]] = OrderedDict()
        self._wall_by_round: OrderedDict[int, tuple] = OrderedDict()

    # ----------------------------------------------------------- clocks ----
    def wall_now_ms(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e3

    # ------------------------------------------------------- lifecycle ----
    def on_submit(self, req) -> RequestTree:
        tree = RequestTree(req.rid, req.arrival_ms, self.wall_now_ms(),
                           deadline_ms=req.deadline_ms,
                           priority=req.priority)
        tree._wait = tree.root.add(
            Span(SPAN_QUEUE_WAIT, req.arrival_ms, self.wall_now_ms()))
        self.open[req.rid] = tree
        return tree

    def on_shed(self, req, t_ms: float, reason: str):
        """Terminal: the depth bound dropped this request (its cause is
        ``shed`` by definition — never a deadline-miss phase)."""
        tree = self.open.pop(req.rid, None)
        if tree is None:
            return
        wall = self.wall_now_ms()
        if tree._wait is not None and not tree._wait.closed:
            tree._wait.close(max(t_ms, tree._wait.t0_ms), wall)
            tree._wait = None
        tree.root.args["shed_reason"] = reason
        tree.root.close(max(t_ms, tree.root.t0_ms), wall)
        tree.state = "shed"
        self._finish(tree)

    def on_admit(self, req, t_ms: float, prefill_wall_ms: float = 0.0):
        """Close the open wait span (initial queue_wait, or the requeue
        child of a fault_recovery span), stamp the prefill, and open a
        decode episode. The prefill is a sim-instant (admission-time
        prefill does not advance the simulated clock) whose real cost is
        quarantined in ``wall_args`` — it becomes a true span when
        chunked prefill lands."""
        tree = self.open.get(req.rid)
        if tree is None:
            return
        wall = self.wall_now_ms()
        if tree._wait is not None:
            if tree._wait.name == SPAN_FAULT_RECOVERY:
                for c in tree._wait.children:
                    if c.name == SPAN_REQUEUE and not c.closed:
                        c.close(t_ms, wall)
                tree._wait.close(t_ms, wall)
            else:
                tree._wait.close(t_ms, wall)
            tree._wait = None
        tree.root.add(Span(SPAN_PREFILL, t_ms, wall,
                           args={"n_requeues": req.n_requeues,
                                 "first_token": True},
                           wall_args={"prefill_ms": prefill_wall_ms})
                      ).close(t_ms, wall)
        tree._decode = tree.root.add(Span(SPAN_DECODE, t_ms, wall))

    def on_round(self, rid: int, t0_ms: float, dt_ms: float,
                 round_idx: int, stall_ms: float = 0.0):
        """One decode-round slice [t0, t0+dt] for an occupied slot.
        ``round_idx`` is the executor dispatch id the slice rode (the
        Perfetto flow-arrow anchor); ``stall_ms`` is the deterministic
        straggler/fault excess of this round over its fault-free
        counterfactual (same latency draw, full mask, no slowdowns)."""
        tree = self.open.get(rid)
        if tree is None or tree._decode is None:
            return
        wall = self.wall_now_ms()
        sl = tree._decode.add(Span(
            SPAN_SLICE, t0_ms, wall,
            args={"round": int(round_idx),
                  "stall_ms": round(float(stall_ms), 9)}))
        sl.close(t0_ms + dt_ms, wall)
        if stall_ms > 0:
            sl.add(Span(SPAN_STALL, t0_ms + dt_ms - stall_ms, wall)
                   ).close(t0_ms + dt_ms, wall)
        self._slices_by_round.setdefault(int(round_idx), []).append(sl)
        while len(self._slices_by_round) > 64:
            self._slices_by_round.popitem(last=False)
        pending = self._wall_by_round.get(int(round_idx))
        if pending is not None:
            sl.wall_args.update(period_ms=pending[0], block_ms=pending[1])

    def on_round_wall(self, round_idx: int, period_ms: float,
                      block_ms: float):
        """Executor-pool emission point: the MEASURED wall attribution of
        one harvested round (pipelined period + unhidden device block
        time), stamped onto every slice that rode it. Quarantined in
        ``wall_args`` — replay comparison never sees it."""
        for sl in self._slices_by_round.get(int(round_idx), ()):
            sl.wall_args.update(period_ms=float(period_ms),
                                block_ms=float(block_ms))
        self._wall_by_round[int(round_idx)] = (float(period_ms),
                                               float(block_ms))
        while len(self._wall_by_round) > 64:
            self._wall_by_round.popitem(last=False)

    def on_requeue(self, req, t_ms: float, fault: dict | None = None):
        """A beyond-budget failure evicted this request: close the decode
        episode (its work is discarded — ``wasted=True`` routes it to the
        fault_recovery bucket in the TTFT decomposition) and open a
        fault_recovery span carrying the triggering fault's identity (the
        flow-arrow anchor back to the injector erasure)."""
        tree = self.open.get(req.rid)
        if tree is None:
            return
        wall = self.wall_now_ms()
        if tree._decode is not None:
            if not tree._decode.closed:
                tree._decode.args["wasted"] = True
                tree._decode.close(t_ms, wall)
            tree._decode = None
        fr = tree.root.add(Span(
            SPAN_FAULT_RECOVERY, t_ms, wall,
            args={"n_requeues": req.n_requeues, **(fault or {})}))
        fr.add(Span(SPAN_REQUEUE, t_ms, wall))
        tree._wait = fr

    def on_heal(self, t_ms: float, reencode_wall_ms: float = 0.0):
        """Replica swap + parity re-encode finished: stamp a heal_wait
        child into every open fault_recovery span. Sim-instant (the 2MR
        swap happens within the round); the re-encode's real cost is
        quarantined in ``wall_args``."""
        wall = self.wall_now_ms()
        for tree in self.open.values():
            fr = tree._wait
            if fr is not None and fr.name == SPAN_FAULT_RECOVERY:
                fr.add(Span(SPAN_HEAL_WAIT, t_ms, wall,
                            wall_args={"reencode_ms": reencode_wall_ms})
                       ).close(t_ms, wall)

    def on_complete(self, req, t_ms: float):
        tree = self.open.pop(req.rid, None)
        if tree is None:
            return
        wall = self.wall_now_ms()
        if tree._decode is not None and not tree._decode.closed:
            tree._decode.close(t_ms, wall)
        tree._decode = None
        tree.root.args.update(n_tokens=len(req.tokens),
                              n_requeues=req.n_requeues,
                              ttft_ms=req.ttft_ms)
        tree.root.close(t_ms, wall)
        tree.state = "completed"
        self._finish(tree)

    def _finish(self, tree: RequestTree):
        self.n_terminal += 1
        self.done.append(tree)

    # ------------------------------------------------------------- read ----
    @property
    def dropped(self) -> int:
        """Terminal trees evicted by the ring bound."""
        return self.n_terminal - len(self.done)

    def trees(self) -> list[RequestTree]:
        """Terminal trees then still-open ones, rid-ordered within each."""
        return sorted(self.done, key=lambda t: t.rid) + \
            sorted(self.open.values(), key=lambda t: t.rid)

    def terminal(self) -> list[RequestTree]:
        return sorted(self.done, key=lambda t: t.rid)

    def comparable(self) -> list[tuple]:
        """Deterministic projection of every tree (replay tests)."""
        return [t.comparable() for t in self.trees()]

    def check_all_closed(self) -> int:
        """Contract check over every TERMINAL tree; returns how many
        passed (raises on the first violation)."""
        for tree in self.terminal():
            tree.check_closed()
        return len(self.done)

    def __len__(self) -> int:
        return len(self.done) + len(self.open)
