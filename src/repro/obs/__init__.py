"""Flight-recorder observability for the coded runtime.

Three pieces, wired through the whole serving stack:

  * ``tracer`` — structured, SimClock+wall-clock dual-stamped events in a
    bounded ring buffer (request/round/fault/planner lifecycles), with a
    one-branch no-op fast path when tracing is off;
  * ``export`` — Perfetto/Chrome ``trace_event`` JSON export (one track
    per shard, per slot, one for rounds/requests/planner), trace
    validation (every injected fault linked to its recovery), Prometheus
    text exposition, and a live ``/metrics`` server;
  * ``shardlog`` — per-shard health timeline (mask transitions,
    erasure/heal counts, unavailability duty cycles) observed directly
    from ``ShardHealthController``;
  * ``perf`` — roofline-anchored per-round cost attribution (useful vs
    parity FLOPs, live ``coded_overhead_frac``) and achieved-vs-roofline
    utilization from the measured round latency;
  * ``history`` — schema-versioned benchmark-trajectory snapshots
    (``BENCH_history.jsonl``) with a direction-aware regression gate;
  * ``spans`` — per-request span trees (queue_wait -> prefill -> decode
    slices + stall -> fault_recovery), SimClock-primary, wall-clock
    quarantined, gap-free over every request lifetime;
  * ``slo`` — TTFT/TPOT decompositions over those trees, deadline-miss
    cause attribution, ``repro_slo_*`` exposition, and the
    ``python -m repro.obs.slo report`` breakdown CLI.
"""
from repro.obs.export import (MetricsServer, chrome_trace, prometheus_text,
                              validate_chrome_trace, write_chrome_trace)
from repro.obs.history import (DEFAULT_TOLERANCES, append_snapshot,
                               check_history, compare, load_history,
                               make_snapshot)
from repro.obs.perf import PerfMonitor, RoundCost, attribute_round_costs
from repro.obs.shardlog import ShardTimeline
from repro.obs.slo import CAUSES, attribute, decompose, summarize
from repro.obs.spans import SPAN_NAMES, RequestTree, Span, SpanTracker
from repro.obs.tracer import (EVENT_KINDS, NULL_RECORDER, FlightRecorder,
                              TraceEvent)

__all__ = [
    "EVENT_KINDS", "FlightRecorder", "NULL_RECORDER", "TraceEvent",
    "ShardTimeline",
    "MetricsServer", "chrome_trace", "prometheus_text",
    "validate_chrome_trace", "write_chrome_trace",
    "PerfMonitor", "RoundCost", "attribute_round_costs",
    "DEFAULT_TOLERANCES", "append_snapshot", "check_history", "compare",
    "load_history", "make_snapshot",
    "SPAN_NAMES", "Span", "RequestTree", "SpanTracker",
    "CAUSES", "attribute", "decompose", "summarize",
]
