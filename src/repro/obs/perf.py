"""Perf observability: roofline-anchored cost attribution for live rounds.

The static ``roofline/`` cost model and the measured round latency in
``RuntimeMetrics.round_ms`` existed side by side but never met: the
runtime could say a round took 4.1 ms and the roofline could say a round
*should* take 0.9 ms, and nothing connected them. ``PerfMonitor`` closes
the loop, per dispatch:

  * **Attribution** (once per code geometry): lower + compile each live
    round variant the executor owns — ``reference`` (full-logits coded
    decode) and ``fused`` (full-Pallas round) — and run
    ``roofline.hlo_cost.analyze_hlo`` over the compiled HLO for
    FLOPs / HBM bytes / wire bytes per dispatch. The same state/params
    are also compiled through the PLAIN (uncoded) model — KV state is
    code-geometry independent, so the coded executor state drives the
    plain trace directly — giving ``useful_flops``; the difference is
    the parity work the code adds:

        coded_overhead_frac = parity_flops / total_flops
                            ≈ r/(T+r) · gemm_share   (falls with T)
        parity_device_equiv = parity_flops / (useful_flops / T)
                            ≈ r · gemm_share         (FLAT in T)

    ``parity_device_equiv`` is the paper's Fig. 2 constant-cost claim as
    a runtime metric: the parity work equals ~r extra devices' worth of
    one shard's useful work, independent of cluster width T.
  * **Utilization** (every harvest): combine the static per-round cost
    with the MEASURED round wall time from ``pool.py`` into
    ``achieved_flops_per_s``, ``hbm_gbs`` and ``roofline_utilization``
    (= roofline-bound step time / measured time, so 1.0 means the round
    runs exactly at the modelled hardware bound). Published three ways:
    ``RuntimeMetrics.perf`` (-> Prometheus gauges), ``perf.counter``
    events on the flight recorder's ``perf`` track (dual-stamped:
    deterministic args carry the static cost, wall-derived values ride in
    ``wall_args`` so traced chaos runs still replay bit-exact), and
    ``summary()`` rows for the benchmarks / ``BENCH_history.jsonl``.

Pallas custom-call kernels are costed via ``kernels.ops.KERNEL_COSTS``
(see ``roofline/hlo_cost.py``); off-TPU interpret mode inlines the kernel
bodies into ordinary dots, so both paths report comparable FLOPs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.obs.tracer import NULL_RECORDER
from repro.roofline.analysis import HW, roofline_terms
from repro.roofline.hlo_cost import analyze_hlo


@dataclasses.dataclass(frozen=True)
class RoundCost:
    """Static per-dispatch cost of one compiled round variant."""
    variant: str
    flops: float                 # total HLO FLOPs per dispatch
    bytes: float                 # HBM bytes per dispatch
    wire_bytes: float
    useful_flops: float          # the plain (uncoded) model's FLOPs
    parity_flops: float          # flops - useful_flops (>= 0)
    coded_overhead_frac: float   # parity / total: falls as T grows
    parity_device_equiv: float   # parity / (useful / T): flat in T (Fig. 2)
    T: int
    r: int
    bound_step_s: float          # roofline-bound round time on `hw`
    dominant: str                # compute | memory | collective
    custom_calls_uncosted: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _analyze(lowerable, *args) -> dict:
    return analyze_hlo(lowerable.lower(*args).compile().as_text())


def _plain_round_flops(stepper, state, toks) -> float:
    """Useful FLOPs: the identical round through the PLAIN model with the
    RAW (uncoded) params. Slot state (KV caches, positions, extras) is
    code-mode independent, so the executor's stacked state compiles
    against the plain decode unchanged."""
    model = stepper.model
    pmodel = dataclasses.replace(
        model, ctx=dataclasses.replace(model.ctx, mode="plain",
                                       fused_body=False))

    def _round(params, state, toks):
        logits, new_state = pmodel.decode(params, state, toks, None)
        last = logits[:, -1:]
        return new_state, jnp.argmax(last, axis=-1).astype(jnp.int32), last

    return _analyze(jax.jit(_round), stepper._raw_params, state,
                    toks)["flops"]


def attribute_round_costs(vstep, state, toks, hw: dict | None = None
                          ) -> dict[str, RoundCost]:
    """Cost every compiled round variant of ``vstep`` over the given slot
    state. Returns {variant: RoundCost} — always ``reference``, plus
    ``fused`` when the executor dispatches the full-Pallas round."""
    hw = dict(hw or HW)
    st = vstep.stepper
    coded = bool(st.coded)
    T = int(st.n_shards)
    r = int(st.model.ctx.code_r) if coded else 0
    valid = st._mask(st.full_mask()) if coded else None

    raw: dict[str, dict] = {
        "reference": _analyze(vstep._round, st.params, state, toks, valid)}
    if vstep.use_fused and coded:
        w_shards, parity_w = vstep._head_shards()
        raw["fused"] = _analyze(vstep._round_fused, st.params, state, toks,
                                valid, w_shards, parity_w)

    useful = raw["reference"]["flops"] if not coded \
        else _plain_round_flops(st, state, toks)

    out: dict[str, RoundCost] = {}
    for variant, cost in raw.items():
        flops = float(cost["flops"])
        parity = max(flops - useful, 0.0)
        terms = roofline_terms(
            {"flops": flops, "bytes accessed": cost["bytes"]},
            {"total": cost["wire_bytes"]}, hw)
        out[variant] = RoundCost(
            variant=variant, flops=flops, bytes=float(cost["bytes"]),
            wire_bytes=float(cost["wire_bytes"]), useful_flops=float(useful),
            parity_flops=parity,
            coded_overhead_frac=parity / flops if flops else 0.0,
            parity_device_equiv=(parity / (useful / T)
                                 if coded and useful else 0.0),
            T=T, r=r, bound_step_s=float(terms["bound_step_s"]),
            dominant=str(terms["dominant"]),
            custom_calls_uncosted=float(
                cost.get("custom_calls_uncosted", 0.0)))
    return out


class PerfMonitor:
    """Per-round achieved-vs-roofline accounting for a slot-pool executor.

    Wired by ``SlotPoolExecutor`` when ``RuntimeConfig.perf`` is on:
    attribution runs lazily at the first harvest (the round is already
    compiled and warm) and re-runs whenever the planner's ``set_code_r``
    changes the (T, r) geometry; every harvest then feeds the measured
    round period through ``observe_round``.
    """

    def __init__(self, metrics=None, tracer=None, hw: dict | None = None):
        self.metrics = metrics
        self.tracer = tracer if tracer is not None else NULL_RECORDER
        self.hw = dict(hw or HW)
        self.costs: dict[str, RoundCost] = {}
        self.n_observed = 0
        self.last_variant: str | None = None
        self.last_round_ms: float | None = None
        self._geom: tuple[int, int] | None = None

    # ------------------------------------------------------- attribution ----
    def attribute(self, executor) -> dict[str, RoundCost]:
        st = executor.stepper
        self.costs = attribute_round_costs(
            executor.vstep, executor.state, executor.last_toks, hw=self.hw)
        self._geom = (int(st.n_shards),
                      int(st.model.ctx.code_r) if st.coded else 0)
        if self.tracer.enabled:
            for cost in self.costs.values():
                # deterministic: everything here comes from compiled HLO
                self.tracer.emit(
                    "perf.attribution", track="perf",
                    variant=cost.variant, flops=cost.flops,
                    hbm_bytes=cost.bytes, wire_bytes=cost.wire_bytes,
                    useful_flops=cost.useful_flops,
                    parity_flops=cost.parity_flops,
                    coded_overhead_frac=cost.coded_overhead_frac,
                    parity_device_equiv=cost.parity_device_equiv,
                    T=cost.T, r=cost.r, dominant=cost.dominant,
                    bound_step_us=cost.bound_step_s * 1e6)
        if self.metrics is not None:
            self.metrics.set_perf(self._static_summary())
        return self.costs

    def _maybe_attribute(self, executor):
        st = executor.stepper
        geom = (int(st.n_shards),
                int(st.model.ctx.code_r) if st.coded else 0)
        if geom != self._geom:
            self.attribute(executor)

    # -------------------------------------------------------- observation ----
    def observe_round(self, executor, wall_ms: float, variant: str):
        """One harvested round: measured period ``wall_ms`` for the round
        ``variant`` that was dispatched."""
        self._maybe_attribute(executor)
        cost = self.costs.get(variant) or self.costs.get("reference")
        if cost is None or wall_ms <= 0:
            return
        self.n_observed += 1
        self.last_variant = variant
        self.last_round_ms = float(wall_ms)
        derived = self.derived(cost, wall_ms)
        if self.metrics is not None:
            self.metrics.set_perf({"variant": variant,
                                   "n_rounds_observed": self.n_observed,
                                   **derived})
        if self.tracer.enabled:
            # counter-track sample: deterministic values in args (Perfetto
            # renders them as counter series), measured ones quarantined in
            # wall_args so replay comparison stays exact
            self.tracer.emit(
                "perf.counter", track="perf",
                variant=variant,
                model_gflops=cost.useful_flops / 1e9,
                coded_overhead_frac=cost.coded_overhead_frac,
                parity_device_equiv=cost.parity_device_equiv,
                wall_args={
                    "round_ms": wall_ms,
                    "achieved_gflops_per_s":
                        derived["achieved_flops_per_s"] / 1e9,
                    "hbm_gbs": derived["hbm_gbs"],
                    "roofline_utilization":
                        derived["roofline_utilization"]})

    def derived(self, cost: RoundCost, round_ms: float) -> dict:
        """Achieved rates for one measured round period."""
        s = round_ms / 1e3
        return {
            "achieved_flops_per_s": cost.flops / s,
            "hbm_gbs": cost.bytes / s / 1e9,
            "roofline_utilization": cost.bound_step_s / s,
            "round_ms": float(round_ms),
        }

    # ------------------------------------------------------------ reading ----
    def _headline(self) -> RoundCost | None:
        if not self.costs:
            return None
        return self.costs.get(self.last_variant or "") \
            or self.costs.get("reference") \
            or next(iter(self.costs.values()))

    def _static_summary(self) -> dict:
        cost = self._headline()
        if cost is None:
            return {}
        return {
            "model_flops": cost.useful_flops,
            "hlo_flops": cost.flops,
            "hbm_bytes": cost.bytes,
            "wire_bytes": cost.wire_bytes,
            "parity_flops": cost.parity_flops,
            "coded_overhead_frac": cost.coded_overhead_frac,
            "parity_device_equiv": cost.parity_device_equiv,
            "bound_step_us": cost.bound_step_s * 1e6,
            "dominant": cost.dominant,
            "T": cost.T, "r": cost.r,
            "custom_calls_uncosted": cost.custom_calls_uncosted,
        }

    def summary(self, round_ms: float | None = None) -> dict:
        """One flat report row: static attribution + achieved rates at
        ``round_ms`` (a steady-state p50 from the bench; defaults to the
        last observed round)."""
        cost = self._headline()
        if cost is None:
            return {}
        out = self._static_summary()
        out["variant"] = cost.variant
        out["n_rounds_observed"] = self.n_observed
        ms = round_ms if round_ms else self.last_round_ms
        if ms:
            out.update(self.derived(cost, ms))
        out["variants"] = {k: v.as_dict() for k, v in self.costs.items()}
        return out
