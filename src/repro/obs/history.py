"""Benchmark trajectory: schema-versioned snapshots + a regression gate.

``BENCH_*.json`` artifacts are overwritten on every run — a perf
regression that lands between two snapshots is invisible. Every bench run
therefore APPENDS one record per (bench, arch) to ``BENCH_history.jsonl``:

    {"schema": 1, "ts": ..., "git_sha": "...", "bench": "serve_throughput",
     "arch": "granite-3-8b",
     "metrics": {"rounds_per_s": ..., "ttft_p99_ms": ...,
                 "roofline_utilization": ..., "coded_overhead_frac": ...,
                 "model_flops": ..., "achieved_flops_per_s": ...}}

The comparator checks the LAST record of each (bench, arch) group against
the median of the previous ``last_n`` records, per metric, with a
direction-aware relative tolerance:

  * ``higher`` (throughput-like: rounds_per_s, achieved_flops_per_s,
    roofline_utilization) — regression when the candidate falls more than
    ``rel`` below the baseline median;
  * ``lower``  (latency-like: ttft_p99_ms) — regression when it rises
    more than ``rel`` above;
  * ``match``  (deterministic: model_flops, coded_overhead_frac) —
    regression when it drifts more than ``rel`` in either direction.

Wall-clock metrics get loose defaults (machine noise); deterministic ones
are tight. CI loosens the wall tolerances further for cross-runner
comparison against the committed baseline (see the perf-trajectory job)
but demonstrates the gate with ``--inject-slowdown``: a synthetic
candidate built from the baseline itself with every throughput metric
scaled down (and every latency metric scaled up) by the given fraction —
deterministic, so the gate MUST fire.

CLI:  python -m repro.obs.history append --path BENCH_history.jsonl \
          --bench serve_throughput --arch granite-3-8b \
          --metric rounds_per_s=123.4
      python -m repro.obs.history check --path BENCH_history.jsonl \
          [--bench B] [--arch A] [--last-n 5] [--tolerance name=rel] \
          [--inject-slowdown 0.3]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import time

SCHEMA_VERSION = 1

#: metric -> (direction, relative tolerance). Documented in DESIGN.md §8.
DEFAULT_TOLERANCES: dict[str, tuple[str, float]] = {
    "rounds_per_s": ("higher", 0.25),
    "tokens_per_s": ("higher", 0.25),
    "achieved_flops_per_s": ("higher", 0.50),
    "roofline_utilization": ("higher", 0.50),
    "hbm_gbs": ("higher", 0.50),
    "ttft_p99_ms": ("lower", 0.50),
    # TPOT (decode ms per generated token after the first, from the
    # obs.slo span decomposition): sim-clock-derived, so tighter than the
    # wall-clock latencies; p50 guards the steady decode rate, p99 the
    # straggler tail
    "tpot_p50_ms": ("lower", 0.35),
    "tpot_p99_ms": ("lower", 0.50),
    "p99_latency_ms": ("lower", 0.50),
    "coded_overhead_frac": ("match", 0.05),
    "parity_device_equiv": ("match", 0.05),
    "model_flops": ("match", 0.01),
}


def git_sha(cwd: str | None = None) -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


# ---------------------------------------------------------------- records ----

def make_snapshot(bench: str, arch: str, metrics: dict, *,
                  sha: str | None = None, ts: float | None = None,
                  extra: dict | None = None) -> dict:
    """One schema-versioned history record (None-valued metrics dropped)."""
    rec = {
        "schema": SCHEMA_VERSION,
        "ts": float(ts) if ts is not None else time.time(),
        "git_sha": sha if sha is not None else git_sha(),
        "bench": str(bench),
        "arch": str(arch),
        "metrics": {k: float(v) for k, v in metrics.items()
                    if isinstance(v, (int, float))},
    }
    if extra:
        rec["extra"] = extra
    return rec


def append_snapshot(path: str, bench: str, arch: str, metrics: dict,
                    **kw) -> dict:
    """Append one record to the JSONL history (creating it if needed)."""
    rec = make_snapshot(bench, arch, metrics, **kw)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")
    return rec


def load_history(path: str) -> list[dict]:
    """Parse the JSONL history; unparsable lines and records from a NEWER
    schema are skipped (forward compatibility), order preserved."""
    records: list[dict] = []
    if not os.path.exists(path):
        return records
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if not isinstance(rec, dict) or "metrics" not in rec:
                continue
            if int(rec.get("schema", 0)) > SCHEMA_VERSION:
                continue
            records.append(rec)
    return records


# ------------------------------------------------------------- comparison ----

def _tolerances(overrides: dict | None) -> dict:
    tol = dict(DEFAULT_TOLERANCES)
    for name, rel in (overrides or {}).items():
        direction = tol.get(name, ("match", 0.0))[0]
        tol[name] = (direction, float(rel))
    return tol


def compare(candidate: dict, baseline_records: list[dict],
            tolerances: dict | None = None, last_n: int = 5) -> list[dict]:
    """Regressions of ``candidate`` vs the per-metric median of the last
    ``last_n`` baseline records. Returns one dict per violated metric;
    metrics missing on either side are skipped (never a false alarm)."""
    tol = _tolerances(tolerances)
    window = baseline_records[-last_n:]
    regressions = []
    for metric, (direction, rel) in sorted(tol.items()):
        cand = candidate.get("metrics", {}).get(metric)
        if cand is None:
            continue
        base_vals = [r["metrics"][metric] for r in window
                     if metric in r.get("metrics", {})]
        if not base_vals:
            continue
        base = statistics.median(base_vals)
        scale = abs(base) if base else 1.0
        if direction == "higher":
            bad = cand < base - rel * scale
        elif direction == "lower":
            bad = cand > base + rel * scale
        else:  # match
            bad = abs(cand - base) > rel * scale
        if bad:
            regressions.append({
                "metric": metric, "direction": direction,
                "tolerance": rel, "baseline_median": base,
                "candidate": cand, "n_baseline": len(base_vals),
                "rel_change": (cand - base) / scale,
            })
    return regressions


def synthetic_slowdown(baseline_records: list[dict], frac: float,
                       tolerances: dict | None = None,
                       last_n: int = 5) -> dict:
    """A synthetic candidate: the baseline medians with every ``higher``
    metric scaled by (1 - frac) and every ``lower`` metric by (1 + frac)
    — the deterministic CI demonstration that the gate fires."""
    tol = _tolerances(tolerances)
    window = baseline_records[-last_n:]
    metrics: dict[str, float] = {}
    for metric, (direction, _) in tol.items():
        vals = [r["metrics"][metric] for r in window
                if metric in r.get("metrics", {})]
        if not vals:
            continue
        base = statistics.median(vals)
        if direction == "higher":
            metrics[metric] = base * (1.0 - frac)
        elif direction == "lower":
            metrics[metric] = base * (1.0 + frac)
        else:
            metrics[metric] = base
    return make_snapshot("synthetic", "synthetic", metrics, sha="synthetic")


def check_history(path: str, bench: str | None = None,
                  arch: str | None = None, last_n: int = 5,
                  tolerances: dict | None = None,
                  inject_slowdown: float = 0.0) -> list[dict]:
    """Gate every (bench, arch) group in the history file. Each group's
    LAST record is compared against the median of its predecessors (a
    group with a single record has no baseline and passes trivially
    unless a slowdown is injected, in which case the synthetic candidate
    is judged against the whole group). Returns one result dict per
    group: {bench, arch, candidate, n_baseline, regressions}."""
    groups: dict[tuple[str, str], list[dict]] = {}
    for rec in load_history(path):
        if bench is not None and rec.get("bench") != bench:
            continue
        if arch is not None and rec.get("arch") != arch:
            continue
        groups.setdefault((rec.get("bench", "?"), rec.get("arch", "?")),
                          []).append(rec)
    results = []
    for (b, a), recs in sorted(groups.items()):
        if inject_slowdown > 0:
            candidate = synthetic_slowdown(recs, inject_slowdown,
                                           tolerances, last_n)
            baseline = recs
        else:
            candidate, baseline = recs[-1], recs[:-1]
        results.append({
            "bench": b, "arch": a,
            "candidate_sha": candidate.get("git_sha"),
            "n_baseline": min(len(baseline), last_n),
            "regressions": compare(candidate, baseline, tolerances, last_n),
        })
    return results


# -------------------------------------------------------------------- CLI ----

def _parse_kv(pairs: list[str], what: str) -> dict:
    out = {}
    for p in pairs:
        if "=" not in p:
            raise SystemExit(f"--{what} wants name=value, got {p!r}")
        k, v = p.split("=", 1)
        out[k] = float(v)
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.obs.history")
    sub = ap.add_subparsers(dest="cmd", required=True)

    a = sub.add_parser("append", help="append one snapshot")
    a.add_argument("--path", default="BENCH_history.jsonl")
    a.add_argument("--bench", required=True)
    a.add_argument("--arch", required=True)
    a.add_argument("--metric", action="append", default=[],
                   metavar="NAME=VALUE")

    c = sub.add_parser("check", help="regression gate over the history")
    c.add_argument("--path", default="BENCH_history.jsonl")
    c.add_argument("--bench", default=None)
    c.add_argument("--arch", default=None)
    c.add_argument("--last-n", type=int, default=5)
    c.add_argument("--tolerance", action="append", default=[],
                   metavar="NAME=REL",
                   help="override a metric's relative tolerance")
    c.add_argument("--inject-slowdown", type=float, default=0.0,
                   help="judge a synthetic candidate built from the "
                        "baseline with this fractional slowdown (gate "
                        "demonstration: MUST exit 1)")
    args = ap.parse_args(argv)

    if args.cmd == "append":
        rec = append_snapshot(args.path, args.bench, args.arch,
                              _parse_kv(args.metric, "metric"))
        print(json.dumps(rec, sort_keys=True))
        return 0

    results = check_history(args.path, bench=args.bench, arch=args.arch,
                            last_n=args.last_n,
                            tolerances=_parse_kv(args.tolerance,
                                                 "tolerance"),
                            inject_slowdown=args.inject_slowdown)
    if not results:
        print(f"history check: no records in {args.path}")
        return 0
    failed = False
    for res in results:
        tag = f"{res['bench']}/{res['arch']}"
        if res["regressions"]:
            failed = True
            print(f"REGRESSION {tag} (baseline n={res['n_baseline']}):")
            for reg in res["regressions"]:
                print(f"  {reg['metric']}: {reg['candidate']:.6g} vs "
                      f"median {reg['baseline_median']:.6g} "
                      f"({reg['rel_change']:+.1%}, {reg['direction']} "
                      f"tol {reg['tolerance']:.0%})")
        else:
            print(f"ok {tag} (baseline n={res['n_baseline']})")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
