"""Per-shard health timeline: the single source of truth for "how long
was device i actually unavailable".

``ShardHealthController`` knows the CURRENT mask and logs (event, action)
pairs, but nothing aggregates them per shard over time: the planner's
EWMA samples the mask per round, and ``BENCH_chaos.json`` reported only
global counters. ``ShardTimeline`` closes that gap — registered as a
health-controller observer it sees every mask transition at its exact
sim timestamp and maintains, per shard:

  * erasure / heal counts (split by heal cause: own recovery vs the 2MR
    replica swap that heals everything at once);
  * closed down-intervals (for the Perfetto shard tracks) and cumulative
    downtime;
  * the unavailability DUTY CYCLE — downtime / observed span — the same
    quantity the adaptive planner estimates per window, now measured
    exactly from the transition log.

Consistency invariant (pinned by tests): at any instant, the set of
shards with an OPEN down-interval equals ``~controller.mask``, and the
timeline's mean duty cycle is the exact integral the planner's per-round
sampling approximates.
"""
from __future__ import annotations

import numpy as np


class ShardTimeline:
    """Observer of ``ShardHealthController`` mask transitions.

    Wire with ``health.observers.append(timeline)`` (the scheduler does
    this automatically). Cost is O(1) per health event — it is always on,
    traced or not.
    """

    def __init__(self, n_shards: int, t0_ms: float = 0.0):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = int(n_shards)
        self.t0_ms = float(t0_ms)
        self.last_ms = float(t0_ms)
        self.down_since = np.full(self.n_shards, np.nan)   # NaN = up
        self.downtime_ms = np.zeros(self.n_shards)
        self.erasures = np.zeros(self.n_shards, np.int64)
        self.recoveries = np.zeros(self.n_shards, np.int64)
        self.replica_heals = np.zeros(self.n_shards, np.int64)
        self.reencodes = 0
        # closed down-intervals: (shard, t_down_ms, t_up_ms, heal_cause)
        self.intervals: list[tuple[int, float, float, str]] = []

    # ------------------------------------------------- observer surface ----
    def on_health(self, ev, action, mask):
        """One applied health event (called by the controller)."""
        # Deferred import: repro.runtime imports repro.obs, so a top-level
        # import here would make `import repro.obs` order-dependent. The
        # controller calling us guarantees the module is already loaded.
        from repro.runtime.health import EventKind, HealthAction
        t = float(ev.time_ms)
        self.last_ms = max(self.last_ms, t)
        if action is HealthAction.NOOP:
            return
        if ev.kind is EventKind.ERASURE:
            self._mark_down(ev.shard, t)
        elif ev.kind is EventKind.RECOVERY:
            self.recoveries[ev.shard] += 1
            self._mark_up(ev.shard, t, "recovery")
        # REPLICA_FAILURE flips no per-shard mask bit; the heal arrives
        # via on_heal_all when the runtime swaps the standby in.

    def on_heal_all(self, t_ms: float, healed: list[int], mask):
        """The 2MR replica swap: every dead shard healed at once."""
        self.last_ms = max(self.last_ms, float(t_ms))
        for s in healed:
            self.replica_heals[s] += 1
            self._mark_up(int(s), float(t_ms), "replica_swap")

    def on_reencode(self, t_ms: float):
        self.last_ms = max(self.last_ms, float(t_ms))
        self.reencodes += 1

    # ---------------------------------------------------------- marking ----
    def _mark_down(self, shard: int, t_ms: float):
        if not (0 <= shard < self.n_shards):
            raise ValueError(f"shard {shard} out of range")
        if np.isnan(self.down_since[shard]):
            self.down_since[shard] = t_ms
            self.erasures[shard] += 1

    def _mark_up(self, shard: int, t_ms: float, cause: str):
        t0 = self.down_since[shard]
        if np.isnan(t0):
            return                       # duplicate heal: nothing open
        self.downtime_ms[shard] += t_ms - t0
        self.intervals.append((shard, float(t0), float(t_ms), cause))
        self.down_since[shard] = np.nan

    # ------------------------------------------------------------- read ----
    @property
    def down_now(self) -> np.ndarray:
        """Bool [n_shards]: shards with an open down-interval."""
        return ~np.isnan(self.down_since)

    def duty_cycle(self, now_ms: float | None = None) -> np.ndarray:
        """Per-shard unavailability fraction over [t0, now]. Open
        intervals count up to ``now`` — the live view the planner's EWMA
        approximates by sampling the mask each round."""
        now = self.last_ms if now_ms is None else float(now_ms)
        span = max(now - self.t0_ms, 0.0)
        if span == 0.0:
            return np.zeros(self.n_shards)
        down = self.downtime_ms.copy()
        open_ = self.down_now
        down[open_] += now - self.down_since[open_]
        return down / span

    def all_intervals(self, now_ms: float | None = None
                      ) -> list[tuple[int, float, float, str]]:
        """Closed intervals plus open ones clipped at ``now`` (export)."""
        now = self.last_ms if now_ms is None else float(now_ms)
        out = list(self.intervals)
        for s in np.flatnonzero(self.down_now):
            t0 = float(self.down_since[s])
            out.append((int(s), t0, max(now, t0), "open"))
        return sorted(out, key=lambda iv: (iv[1], iv[0]))

    def snapshot(self, now_ms: float | None = None) -> dict:
        """JSON-serialisable per-shard report (BENCH_chaos source)."""
        now = self.last_ms if now_ms is None else float(now_ms)
        duty = self.duty_cycle(now)
        shards = [{
            "shard": i,
            "erasures": int(self.erasures[i]),
            "recoveries": int(self.recoveries[i]),
            "replica_heals": int(self.replica_heals[i]),
            "downtime_ms": float(self.downtime_ms[i]),
            "duty_cycle": float(duty[i]),
            "down_now": bool(self.down_now[i]),
        } for i in range(self.n_shards)]
        return {
            "t0_ms": self.t0_ms,
            "now_ms": now,
            "reencodes": self.reencodes,
            "mean_duty_cycle": float(duty.mean()),
            "max_duty_cycle": float(duty.max()),
            "total_erasures": int(self.erasures.sum()),
            "shards": shards,
        }
