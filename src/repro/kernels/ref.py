"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)


def cdc_encode_ref(w_shards: jax.Array, gen: jax.Array) -> jax.Array:
    """[T, k, n] x [r, T] -> [r, k, n]."""
    acc = jnp.tensordot(gen.astype(jnp.float32),
                        w_shards.astype(jnp.float32), axes=[[1], [0]])
    return acc.astype(w_shards.dtype)


def cdc_decode_ref(y_shards: jax.Array, parity: jax.Array,
                   valid: jax.Array) -> jax.Array:
    """r=1 recovery, paper Eq. 12. y: [T, m, n], parity: [m, n], valid: [T]."""
    vmask = valid.astype(jnp.float32)[:, None, None]
    y = y_shards.astype(jnp.float32) * vmask
    missing = parity.astype(jnp.float32) - y.sum(0)
    out = y + (1.0 - vmask) * missing[None]
    return out.astype(y_shards.dtype)


def fused_head_argmax_ref(x: jax.Array, w_shards: jax.Array,
                          parity_w: jax.Array, valid: jax.Array,
                          vocab: int) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused coded head: shard GEMMs + Eq. 12 recovery +
    argmax over the merged logical vocabulary. Returns (token, max_logit)."""
    y = jnp.einsum("bk,tkn->tbn", x.astype(jnp.float32),
                   w_shards.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    p = jnp.dot(x.astype(jnp.float32), parity_w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    rec = cdc_decode_ref(y, p, valid)             # [T, b, m_l]
    merged = jnp.moveaxis(rec, 0, -2)             # [b, T, m_l]
    merged = merged.reshape(merged.shape[0], -1)[:, :vocab]
    return (jnp.argmax(merged, axis=-1).astype(jnp.int32),
            jnp.max(merged, axis=-1))


def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6
                ) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * gamma.astype(jnp.float32)).astype(x.dtype)
