"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)


def cdc_encode_ref(w_shards: jax.Array, gen: jax.Array) -> jax.Array:
    """[T, k, n] x [r, T] -> [r, k, n]."""
    acc = jnp.tensordot(gen.astype(jnp.float32),
                        w_shards.astype(jnp.float32), axes=[[1], [0]])
    return acc.astype(w_shards.dtype)


def cdc_decode_ref(y_shards: jax.Array, parity: jax.Array,
                   valid: jax.Array) -> jax.Array:
    """r=1 recovery, paper Eq. 12. y: [T, m, n], parity: [m, n], valid: [T]."""
    vmask = valid.astype(jnp.float32)[:, None, None]
    y = y_shards.astype(jnp.float32) * vmask
    missing = parity.astype(jnp.float32) - y.sum(0)
    out = y + (1.0 - vmask) * missing[None]
    return out.astype(y_shards.dtype)


def fused_head_argmax_ref(x: jax.Array, w_shards: jax.Array,
                          parity_w: jax.Array, valid: jax.Array,
                          vocab: int) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused coded head: shard GEMMs + Eq. 12 recovery +
    argmax over the merged logical vocabulary. Returns (token, max_logit)."""
    y = jnp.einsum("bk,tkn->tbn", x.astype(jnp.float32),
                   w_shards.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    p = jnp.dot(x.astype(jnp.float32), parity_w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    rec = cdc_decode_ref(y, p, valid)             # [T, b, m_l]
    merged = jnp.moveaxis(rec, 0, -2)             # [b, T, m_l]
    merged = merged.reshape(merged.shape[0], -1)[:, :vocab]
    return (jnp.argmax(merged, axis=-1).astype(jnp.int32),
            jnp.max(merged, axis=-1))


def _eq12_combine_ref(y: jax.Array, p: jax.Array, gen: jax.Array,
                      valid: jax.Array, esel: jax.Array,
                      coef: jax.Array) -> jax.Array:
    """Shared Eq. 12 tail of the in-body kernels: zero dead shards,
    rebuild the missing one from its selected parity equation, emit the
    merged [rows, T, m_l] layout. y: [T, rows, m_l] f32, p: [r, rows, m_l]
    f32, esel/coef: per-column plan from ``cdc_matmul.eq12_plan``."""
    vmask = valid[:, None, None]
    yz = jnp.where(vmask, y, 0.0)
    residual = p - jnp.tensordot(gen.astype(jnp.float32), yz,
                                 axes=[[1], [0]])          # [r, rows, m_l]
    onehot = jnp.arange(p.shape[0])[:, None] == esel[None, :]   # [r, m_l]
    pick = jnp.sum(jnp.where(onehot[:, None, :], residual, 0.0), axis=0)
    missing = pick * coef[None, :].astype(jnp.float32)
    out = jnp.where(vmask, yz, missing[None])
    return jnp.moveaxis(out, 0, 1)                         # [rows, T, m_l]


def cdc_coded_matmul_ref(x: jax.Array, w_shards: jax.Array,
                         parity_w: jax.Array, gen: jax.Array,
                         esel: jax.Array, coef: jax.Array,
                         valid: jax.Array, *, gamma: jax.Array | None = None,
                         eps: float = 1e-5, out_dtype=None) -> jax.Array:
    """Oracle for ``cdc_coded_matmul_pallas``: (rmsnorm?) + T shard GEMMs
    + r parity GEMMs + in-register Eq. 12 decode + merge, all f32.
    Returns merged [rows, T, m_l]."""
    xf = x.astype(jnp.float32)
    if gamma is not None:
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        xf = xf * jax.lax.rsqrt(var + eps) \
            * gamma.astype(jnp.float32)[None]
    y = jnp.einsum("bk,tkn->tbn", xf, w_shards.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    p = jnp.einsum("bk,rkn->rbn", xf, parity_w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    out = _eq12_combine_ref(y, p, gen, valid, esel, coef)
    return out.astype(out_dtype or x.dtype)


def cdc_decode_merge_ref(ys: jax.Array, parity: jax.Array, gen: jax.Array,
                         esel: jax.Array, coef: jax.Array,
                         valid: jax.Array, out_dtype=None) -> jax.Array:
    """Oracle for ``cdc_decode_merge_pallas``: Eq. 12 decode + merge of
    already-computed shard outputs ys [T, rows, m_l] with UNFOLDED parity
    [r, rows, m_l]. Returns merged [rows, T, m_l]."""
    out = _eq12_combine_ref(ys.astype(jnp.float32),
                            parity.astype(jnp.float32), gen, valid, esel,
                            coef)
    return out.astype(out_dtype or ys.dtype)


def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6
                ) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * gamma.astype(jnp.float32)).astype(x.dtype)
