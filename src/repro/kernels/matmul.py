"""Blocked MXU-aligned matmul Pallas kernel — the GEMM the coded layers ride.

TPU-native tiling: (bm x bk) @ (bk x bn) MXU tiles, fp32 accumulation in the
output block across the sequential K grid dimension (TPU grids execute
serially along the last axis, so `k == 0` initialisation + accumulate is the
canonical pattern). Block sizes default to 128/256 multiples to match the
MXU's 128x128 systolic array and keep the working set inside VMEM:
  VMEM bytes ~= bm*bk + bk*bn + bm*bn  (x2 for bf16 in, x4 for fp32 acc).
The jit'd wrapper lives in ops.py; the pure-jnp oracle in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, acc_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret"))
def matmul_pallas(x: jax.Array, w: jax.Array, *, bm: int = 128, bn: int = 128,
                  bk: int = 128, out_dtype=None, interpret: bool = False
                  ) -> jax.Array:
    """x: [m, k] @ w: [k, n] -> [m, n] with fp32 accumulation.

    m, k, n must be divisible by the block sizes (callers pad; the model
    configs keep every coded dim 128-aligned via ``pad_for_code``).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    out_dtype = out_dtype or x.dtype
    grid = (m // bm, n // bn, k // bk)
    acc = pl.pallas_call(
        functools.partial(_matmul_kernel, acc_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)
    return acc.astype(out_dtype)
