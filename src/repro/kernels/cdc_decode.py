"""Pallas kernel for the r=1 recovery combine (paper Eq. 12).

y_missing = parity - sum_{i valid} y_i, then scatter into the erased slot:
  out[i] = valid[i] ? y[i] : (parity - sum_j valid[j]*y[j])
This is the paper's "close-to-zero" recovery: one fused elementwise pass over
the gathered shard outputs — no recompute, no weight reload. Memory-bound:
reads (T+1) blocks, writes T. The general r>1 MDS decode solves a tiny system
and stays in plain JAX (repro.core.coding/coded_layer); this kernel is the
hot path that runs on EVERY request in coded serving.

Layout: shard outputs stacked [T, rows, m_l]; tiles (rows, bn) with the full
shard axis resident (T <= 64), validity mask as a [T] VMEM block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(valid_ref, y_ref, p_ref, o_ref):
    # y_ref: [T, bm, bn]; p_ref: [1, bm, bn]; valid_ref: [T]
    y = y_ref[...].astype(jnp.float32)
    valid = valid_ref[...]
    vmask = valid.astype(jnp.float32)[:, None, None]
    zeroed = y * vmask                       # kill garbage in erased slots
    total = jnp.sum(zeroed, axis=0)          # sum of the valid shards
    missing = p_ref[0].astype(jnp.float32) - total  # Eq. 12
    out = zeroed + (1.0 - vmask) * missing[None]
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def cdc_decode_pallas(y_shards: jax.Array, parity: jax.Array,
                      valid: jax.Array, *, bm: int = 128, bn: int = 256,
                      interpret: bool = False) -> jax.Array:
    """Recover <=1 erased shard. y: [T, m, n], parity: [m, n], valid: [T]."""
    t, m, n = y_shards.shape
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    return pl.pallas_call(
        _decode_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((t,), lambda i, j: (0,)),
            pl.BlockSpec((t, bm, bn), lambda i, j: (0, i, j)),
            pl.BlockSpec((1, bm, bn), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((t, bm, bn), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((t, m, n), y_shards.dtype),
        interpret=interpret,
    )(valid, y_shards, parity[None])
