"""Pallas kernels for the CDC decode hot path.

``cdc_decode_pallas`` — the r=1 recovery combine (paper Eq. 12):
y_missing = parity - sum_{i valid} y_i, then scatter into the erased slot:
  out[i] = valid[i] ? y[i] : (parity - sum_j valid[j]*y[j])
This is the paper's "close-to-zero" recovery: one fused elementwise pass over
the gathered shard outputs — no recompute, no weight reload. Memory-bound:
reads (T+1) blocks, writes T. The general r>1 MDS decode solves a tiny system
and stays in plain JAX (repro.core.coding/coded_layer); this kernel is the
hot path that runs on EVERY request in coded serving.

Layout: shard outputs stacked [T, rows, m_l]; tiles (rows, bn) with the full
shard axis resident (T <= 64), validity mask as a [T] VMEM block.

``cdc_fused_head_argmax_pallas`` — the batched-executor decode step: coded
LM-head GEMM + Eq. 12 parity decode + greedy argmax in ONE kernel. Per
column tile it computes every shard's head output y_d = x @ W_d plus the
sum-parity output p = x @ W_cdc0, recovers an erased shard in-register, and
folds a running (max, argmax) over the merged vocabulary — the [B, vocab]
logits tensor is never materialised in HBM.

Erasure limit (ASYMMETRY with the reference path, by design): both kernels
here consume exactly ONE parity equation — the all-ones sum row (paper
Eq. 12) — so they recover at most ONE erased shard even when the code's
budget is larger (dedicated layout with r=2 tolerates 2). The reference
path (full logits + ``core.coding.decode_outputs`` MDS solve) covers the
full budget. ``executor.vstep.round`` counts the host mask BEFORE
dispatch and routes 2+-erasure rounds to the reference variant, and
``kernels.ops`` raises on host-concrete masks beyond the limit — an
in-budget multi-erasure round degrades to the slower exact path, never to
a silently wrong token. (The in-BODY fused kernels in ``cdc_matmul``
share the regime but generalise the equation: see ``eq12_plan``.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(valid_ref, y_ref, p_ref, o_ref):
    # y_ref: [T, bm, bn]; p_ref: [1, bm, bn]; valid_ref: [T]
    y = y_ref[...].astype(jnp.float32)
    valid = valid_ref[...]
    vmask = valid.astype(jnp.float32)[:, None, None]
    zeroed = y * vmask                       # kill garbage in erased slots
    total = jnp.sum(zeroed, axis=0)          # sum of the valid shards
    missing = p_ref[0].astype(jnp.float32) - total  # Eq. 12
    out = zeroed + (1.0 - vmask) * missing[None]
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def cdc_decode_pallas(y_shards: jax.Array, parity: jax.Array,
                      valid: jax.Array, *, bm: int = 128, bn: int = 256,
                      interpret: bool = False) -> jax.Array:
    """Recover <=1 erased shard. y: [T, m, n], parity: [m, n], valid: [T]."""
    t, m, n = y_shards.shape
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    return pl.pallas_call(
        _decode_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((t,), lambda i, j: (0,)),
            pl.BlockSpec((t, bm, bn), lambda i, j: (0, i, j)),
            pl.BlockSpec((1, bm, bn), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((t, bm, bn), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((t, m, n), y_shards.dtype),
        interpret=interpret,
    )(valid, y_shards, parity[None])


# ------------------------------------------------------ fused head+argmax ----

NEG_INF = -1e30  # python float: jnp scalars would be captured consts


def _fused_head_kernel(valid_ref, x_ref, w_ref, pw_ref, oval_ref, oidx_ref,
                       *, m_l: int, bn: int, vocab: int):
    """One vocab tile of the fused coded head: GEMM -> Eq. 12 -> running
    argmax. The grid walks the shard-local column tiles sequentially; the
    (b, 1) output blocks are revisited at every step and carry the running
    (max logit, global argmax) across tiles."""
    j = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)            # [b, k]
    w = w_ref[...].astype(jnp.float32)            # [T, k, bn]
    pw = pw_ref[...].astype(jnp.float32)          # [k, bn]
    valid = valid_ref[...]                        # [T] bool
    T = w.shape[0]

    # coded matmul: every shard's tile plus the sum-parity tile (MXU)
    y = jnp.einsum("bk,tkn->tbn", x, w,
                   preferred_element_type=jnp.float32)
    p = jnp.dot(x, pw, preferred_element_type=jnp.float32)   # [b, bn]

    # parity decode (Eq. 12): zero the erased shard, rebuild it from parity
    vm = valid.astype(jnp.float32)[:, None, None]
    yz = y * vm
    missing = p - jnp.sum(yz, axis=0)             # [b, bn]
    rec = yz + (1.0 - vm) * missing[None]         # [T, b, bn]

    # merged-vocab column ids: shard t's tile covers t*m_l + j*bn + c
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (T, bn), 0)
    c_ids = jax.lax.broadcasted_iota(jnp.int32, (T, bn), 1)
    gid = t_ids * m_l + j * bn + c_ids            # [T, bn]

    logits = jnp.moveaxis(rec, 1, 0)              # [b, T, bn]
    logits = jnp.where((gid < vocab)[None], logits, NEG_INF)
    flat = logits.reshape(logits.shape[0], T * bn)
    # gid is strictly increasing along the flat (t-major) order, so the
    # first-occurrence argmax below is also the smallest global id
    vmax = jnp.max(flat, axis=1)                  # [b]
    amax = jnp.argmax(flat, axis=1).astype(jnp.int32)
    gbest = (amax // bn) * m_l + j * bn + amax % bn

    nv, ni = vmax[:, None], gbest[:, None]

    @pl.when(j == 0)
    def _():
        oval_ref[...] = nv
        oidx_ref[...] = ni

    @pl.when(j > 0)
    def _():
        cv, ci = oval_ref[...], oidx_ref[...]
        # strict argmax semantics: ties go to the smaller global id
        better = (nv > cv) | ((nv == cv) & (ni < ci))
        oval_ref[...] = jnp.where(better, nv, cv)
        oidx_ref[...] = jnp.where(better, ni, ci)


@functools.partial(jax.jit,
                   static_argnames=("vocab", "bn", "interpret"))
def cdc_fused_head_argmax_pallas(x: jax.Array, w_shards: jax.Array,
                                 parity_w: jax.Array, valid: jax.Array, *,
                                 vocab: int, bn: int = 128,
                                 interpret: bool = False
                                 ) -> tuple[jax.Array, jax.Array]:
    """Fused coded LM head + parity decode + greedy argmax.

    x:        [b, k] last-position hidden states (post final norm).
    w_shards: [T, k, m_l] column shards of the (padded) head weight.
    parity_w: [k, m_l] sum-parity head weight (generator row 0, all-ones).
    valid:    [T] bool shard validity; at most ONE False (Eq. 12 regime —
              the caller falls back to the reference MDS path beyond that).
    vocab:    logical vocabulary (merged columns >= vocab never win).

    Returns (token [b] int32, max_logit [b] f32) — argmax over the merged
    [b, T*m_l] logits, which are never materialised.
    """
    t, k, m_l = w_shards.shape
    b = x.shape[0]
    bn = min(bn, m_l)
    while m_l % bn:
        bn //= 2
    kernel = functools.partial(_fused_head_kernel, m_l=m_l, bn=bn,
                               vocab=vocab)
    val, idx = pl.pallas_call(
        kernel,
        grid=(m_l // bn,),
        in_specs=[
            pl.BlockSpec((t,), lambda j: (0,)),
            pl.BlockSpec((b, k), lambda j: (0, 0)),
            pl.BlockSpec((t, k, bn), lambda j: (0, 0, j)),
            pl.BlockSpec((k, bn), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((b, 1), lambda j: (0, 0)),
            pl.BlockSpec((b, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(valid, x, w_shards, parity_w)
    return idx[:, 0], val[:, 0]
