"""Fused RMSNorm (+ optional residual) Pallas kernel.

Every transformer block in the zoo normalises twice per layer; fusing the
reduction + scale into one VMEM pass keeps it VPU-bound instead of three HBM
round-trips. Rows are tiled (bm x d) with the full feature dim resident (the
reduction axis must live in one block); fp32 math regardless of input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * g_ref[...].astype(jnp.float32)[None]
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "eps", "interpret"))
def rmsnorm_pallas(x: jax.Array, gamma: jax.Array, *, bm: int = 256,
                   eps: float = 1e-6, interpret: bool = False) -> jax.Array:
    """x: [rows, d], gamma: [d] -> [rows, d]."""
    m, d = x.shape
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, gamma)
