"""Pallas kernel for the OFFLINE parity-weight encode (paper Eq. 7/11).

parity[j] = sum_i gen[j, i] * W_i over the T stacked weight shards — a
tiny-contraction GEMM (T <= 64) over large [k, m_l] tiles. Memory-bound:
reads T*k*m_l weights once, writes r*k*m_l parities. Tiled (bk x bn) over the
weight plane with the full (small) shard axis resident per tile; generator
coefficients ride along as a VMEM-resident [r, T] block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(gen_ref, w_ref, o_ref):
    # w_ref: [T, bk, bn]; gen_ref: [r, T]; o_ref: [r, bk, bn]
    w = w_ref[...].astype(jnp.float32)
    gen = gen_ref[...].astype(jnp.float32)
    o_ref[...] = jax.lax.dot_general(
        gen, w.reshape(w.shape[0], -1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "bn", "interpret"))
def cdc_encode_pallas(w_shards: jax.Array, gen: jax.Array, *, bk: int = 256,
                      bn: int = 256, interpret: bool = False) -> jax.Array:
    """[T, k, m_l] shards x [r, T] generator -> [r, k, m_l] parity weights."""
    t, k, n = w_shards.shape
    r, t2 = gen.shape
    assert t == t2
    bk, bn = min(bk, k), min(bn, n)
    assert k % bk == 0 and n % bn == 0, (k, n, bk, bn)
    return pl.pallas_call(
        _encode_kernel,
        grid=(k // bk, n // bn),
        in_specs=[
            pl.BlockSpec((r, t), lambda i, j: (0, 0)),
            pl.BlockSpec((t, bk, bn), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((r, bk, bn), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((r, k, n), w_shards.dtype),
        interpret=interpret,
    )(gen, w_shards)
