"""Fused Pallas kernels for the IN-BODY coded decode round.

The Pallas fast path used to end at the LM head (``cdc_decode.py``): every
in-body coded GEMM — attention QKV, FFN up/gate, and their erasure
recovery — still round-tripped T shard outputs plus r parity outputs
through HBM on the reference path, then re-read them for the Eq. 12
decode and the merge. These kernels close that gap: ONE kernel computes
the T shard GEMMs and the parity GEMMs tile-by-tile, applies the paper's
Eq. 12 parity reconstruction for masked shards in-register, and writes
the MERGED activation directly — per-shard outputs never exist in HBM.

``cdc_coded_matmul_pallas`` — fused coded matmul + decode + merge:
    x [rows, k] @ w_shards [T, k, m_l] (+ parity_w [r, k, m_l])
      -> merged [rows, T, m_l]      (reshape to [rows, T*m_l] is free:
                                     the kernel writes merge order directly)
  Optionally folds the preceding RMSNorm into the same VMEM pass
  (``gamma`` — the stretch fusion: norm + coded GEMM + decode + merge).

``cdc_decode_merge_pallas`` — decode-and-merge of ALREADY-computed shard
outputs (the ``core.decode_and_merge`` tail, e.g. outputs gathered by
``dist.collectives``): ys [T, rows, m_l] + parity [r, rows, m_l]
-> merged [rows, T, m_l], same in-register Eq. 12 pass.

Erasure regime (both kernels): at most ONE erased shard — the paper's
Eq. 12 sum-code recovery, generalised to any generator row via a
per-column equation plan (``eq12_plan``). For the folded/staggered parity
placement a dead device also kills one parity *slice* per equation, so
the plan selects, per output column, the lowest-index parity equation
whose slice survived (exactly ``decode_folded``'s top-1 selection) and
bakes the 1/gen[e, d] back-substitution coefficient in. Beyond one
erasure the callers (``kernels.ops``, ``executor.vstep``) fall back to
the reference MDS path — never a silent wrong answer.

Tile layout: grid (rows/bm, m_l/bn); per instance the FULL contraction
dim k and the full (small) shard axis are resident, so the recovery math
never leaves VMEM:
  VMEM floats ~= bm*k + (T+r)*k*bn + (T+r)*bm*bn + bm*T*bn
(k resident like the fused-head kernel; callers shrink bm/bn for large k).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.coded_layer import folded_slot_map


def eq12_plan(spec, valid: jax.Array, valid_parity: jax.Array,
              m_l: int) -> tuple[jax.Array, jax.Array]:
    """Per-output-column decode plan for the <=1-erasure regime.

    Returns (esel [m_l] int32, coef [m_l] f32): column c of a missing
    shard d is rebuilt as  coef[c] * (p_{esel[c]} - sum_i gen[esel[c],i]
    * y_i)  with coef = 1/gen[esel[c], d]. Dedicated layout (parity rows
    intact) always uses the sum row (esel=0, coef=1). Folded layout picks,
    per slice, the lowest-index equation whose staggered parity slice is
    still on a healthy device — the same top-1 selection as
    ``core.decode_folded``, so fused ≡ reference under every in-budget
    mask. Fully traceable: the mask stays a runtime array.
    """
    code = spec.code
    T, r = code.n_shards, code.n_parity
    gen = jnp.asarray(code.generator, jnp.float32)          # [r, T]
    d = jnp.argmin(valid)               # first dead shard (0 if none dead)
    if spec.layout == "folded" and r > 1 and m_l % T == 0:
        w = m_l // T
        smap = jnp.asarray(folded_slot_map(T, r))           # [r, T]
        pv = valid_parity[smap]                             # [r, T] alive?
        eq_score = jnp.where(pv, 1.0, -1.0) \
            - jnp.arange(r, dtype=jnp.float32)[:, None] * 1e-3
        esel = jnp.repeat(jnp.argmax(eq_score, axis=0).astype(jnp.int32),
                          w, total_repeat_length=m_l)
    else:
        esel = jnp.zeros((m_l,), jnp.int32)
    coef = (1.0 / gen[esel, d]).astype(jnp.float32)         # [m_l]
    return esel, coef


def _decode_combine(y, p, gen, valid, esel, coef):
    """Shared in-register tail: zero dead shards, Eq. 12-reconstruct the
    missing one from its selected parity equation, emit merged layout.

    y: [T, bm, bn], p: [r, bm, bn] (f32); returns [bm, T, bn] f32."""
    T = y.shape[0]
    r = p.shape[0]
    vmask = valid[:, None, None]
    yz = jnp.where(vmask, y, 0.0)
    # residual_j = p_j - sum_i gen[j, i] * y_i  (dead shards zeroed above)
    residual = p - jnp.tensordot(gen, yz, axes=[[1], [0]])  # [r, bm, bn]
    # per-column equation pick (esel) without NaN propagation from
    # never-selected rows: where(), not a multiply-by-onehot
    rows = jax.lax.broadcasted_iota(jnp.int32, (r, y.shape[2]), 0)
    onehot = rows == esel[None, :]                          # [r, bn]
    pick = jnp.sum(jnp.where(onehot[:, None, :], residual, 0.0), axis=0)
    missing = pick * coef[None, :]                          # [bm, bn]
    out = jnp.where(vmask, yz, missing[None])               # [T, bm, bn]
    return jnp.moveaxis(out, 0, 1)                          # [bm, T, bn]


# ------------------------------------------------- fused coded matmul ----

def _coded_matmul_kernel(valid_ref, esel_ref, coef_ref, gen_ref, x_ref,
                         w_ref, pw_ref, *rest, fuse_norm: bool, eps: float):
    if fuse_norm:
        gamma_ref, o_ref = rest
    else:
        (o_ref,) = rest
    x = x_ref[...].astype(jnp.float32)                      # [bm, k]
    if fuse_norm:
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        x = x * jax.lax.rsqrt(var + eps) \
            * gamma_ref[...].astype(jnp.float32)[None]
    w = w_ref[...].astype(jnp.float32)                      # [T, k, bn]
    pw = pw_ref[...].astype(jnp.float32)                    # [r, k, bn]
    # the T shard GEMMs + the r parity GEMMs for this tile (MXU)
    y = jnp.einsum("bk,tkn->tbn", x, w,
                   preferred_element_type=jnp.float32)
    p = jnp.einsum("bk,rkn->rbn", x, pw,
                   preferred_element_type=jnp.float32)
    out = _decode_combine(y, p, gen_ref[...].astype(jnp.float32),
                          valid_ref[...], esel_ref[...], coef_ref[...])
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "eps", "out_dtype",
                                             "interpret"))
def cdc_coded_matmul_pallas(x: jax.Array, w_shards: jax.Array,
                            parity_w: jax.Array, gen: jax.Array,
                            esel: jax.Array, coef: jax.Array,
                            valid: jax.Array, *, gamma: jax.Array | None
                            = None, eps: float = 1e-5, bm: int = 128,
                            bn: int = 128, out_dtype=None,
                            interpret: bool = False) -> jax.Array:
    """Fused (rmsnorm?) + coded shard GEMMs + Eq. 12 decode + merge.

    x:        [rows, k] activations (pre-norm when ``gamma`` is given).
    w_shards: [T, k, m_l] column shards of the weight.
    parity_w: [r, k, m_l] parity weights in UNFOLDED/dedicated layout
              (callers unfold the slot-major folded layout first).
    gen:      [r, T] generator rows; esel/coef: the ``eq12_plan``.
    valid:    [T] bool; at most ONE False (callers fall back beyond).

    Returns merged [rows, T, m_l] — ``reshape(rows, T*m_l)`` IS the
    merged activation (merge order is written directly; no transpose,
    no per-shard HBM array ever exists).
    """
    rows, k = x.shape
    t, k2, m_l = w_shards.shape
    r = parity_w.shape[0]
    assert k == k2, (x.shape, w_shards.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn = min(bm, rows), min(bn, m_l)
    rows_p = -(-rows // bm) * bm
    m_l_p = -(-m_l // bn) * bn
    if rows_p != rows:
        x = jnp.pad(x, ((0, rows_p - rows), (0, 0)))
    if m_l_p != m_l:
        padn = ((0, 0), (0, 0), (0, m_l_p - m_l))
        w_shards = jnp.pad(w_shards, padn)
        parity_w = jnp.pad(parity_w, padn)
        esel = jnp.pad(esel, (0, m_l_p - m_l))
        coef = jnp.pad(coef, (0, m_l_p - m_l), constant_values=1.0)
    fuse_norm = gamma is not None
    kernel = functools.partial(_coded_matmul_kernel, fuse_norm=fuse_norm,
                               eps=eps)
    in_specs = [
        pl.BlockSpec((t,), lambda i, j: (0,)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
        pl.BlockSpec((bn,), lambda i, j: (j,)),
        pl.BlockSpec((r, t), lambda i, j: (0, 0)),
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((t, k, bn), lambda i, j: (0, 0, j)),
        pl.BlockSpec((r, k, bn), lambda i, j: (0, 0, j)),
    ]
    args = [valid, esel, coef, gen, x, w_shards, parity_w]
    if fuse_norm:
        in_specs.append(pl.BlockSpec((k,), lambda i, j: (0,)))
        args.append(gamma)
    out = pl.pallas_call(
        kernel,
        grid=(rows_p // bm, m_l_p // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, t, bn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((rows_p, t, m_l_p), out_dtype),
        interpret=interpret,
    )(*args)
    if rows_p != rows or m_l_p != m_l:
        out = out[:rows, :, :m_l]
    return out


# --------------------------------------------------- decode-and-merge ----

def _decode_merge_kernel(valid_ref, esel_ref, coef_ref, gen_ref, y_ref,
                         p_ref, o_ref):
    out = _decode_combine(y_ref[...].astype(jnp.float32),
                          p_ref[...].astype(jnp.float32),
                          gen_ref[...].astype(jnp.float32),
                          valid_ref[...], esel_ref[...], coef_ref[...])
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "out_dtype",
                                             "interpret"))
def cdc_decode_merge_pallas(ys: jax.Array, parity: jax.Array,
                            gen: jax.Array, esel: jax.Array,
                            coef: jax.Array, valid: jax.Array, *,
                            bm: int = 128, bn: int = 128, out_dtype=None,
                            interpret: bool = False) -> jax.Array:
    """Eq. 12 decode + merge of already-computed shard outputs.

    ys: [T, rows, m_l] shard outputs; parity: [r, rows, m_l] UNFOLDED
    parity outputs; valid: [T] bool, at most one False. Returns merged
    [rows, T, m_l] (reshape to [rows, T*m_l] is free). One fused
    elementwise pass: the stacked shard outputs are read once and only
    the merged activation is written.
    """
    t, rows, m_l = ys.shape
    r = parity.shape[0]
    out_dtype = out_dtype or ys.dtype
    bm, bn = min(bm, rows), min(bn, m_l)
    rows_p = -(-rows // bm) * bm
    m_l_p = -(-m_l // bn) * bn
    if rows_p != rows or m_l_p != m_l:
        pad = ((0, 0), (0, rows_p - rows), (0, m_l_p - m_l))
        ys = jnp.pad(ys, pad)
        parity = jnp.pad(parity, pad)
        esel = jnp.pad(esel, (0, m_l_p - m_l))
        coef = jnp.pad(coef, (0, m_l_p - m_l), constant_values=1.0)
    out = pl.pallas_call(
        _decode_merge_kernel,
        grid=(rows_p // bm, m_l_p // bn),
        in_specs=[
            pl.BlockSpec((t,), lambda i, j: (0,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((r, t), lambda i, j: (0, 0)),
            pl.BlockSpec((t, bm, bn), lambda i, j: (0, i, j)),
            pl.BlockSpec((r, bm, bn), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((bm, t, bn), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((rows_p, t, m_l_p), out_dtype),
        interpret=interpret,
    )(valid, esel, coef, gen, ys, parity)
    if rows_p != rows or m_l_p != m_l:
        out = out[:rows, :, :m_l]
    return out
