"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; on this CPU container they execute in
``interpret=True`` mode (the kernel body runs in Python on CPU) so every test
and benchmark exercises the real kernel logic. ``use_pallas=False`` (or
backends where even interpret is undesirable for perf) falls back to the
ref oracle -- identical math, so the swap is safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cdc_decode import (cdc_decode_pallas,
                                      cdc_fused_head_argmax_pallas)
from repro.kernels.cdc_encode import cdc_encode_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul(x, w, *, out_dtype=None, use_pallas=True, **block_kw):
    if not use_pallas:
        return ref.matmul_ref(x, w, out_dtype)
    return matmul_pallas(x, w, out_dtype=out_dtype, interpret=_interpret(),
                         **block_kw)


def cdc_encode(w_shards, gen, *, use_pallas=True, **block_kw):
    gen = jnp.asarray(gen, dtype=jnp.float32)
    if not use_pallas:
        return ref.cdc_encode_ref(w_shards, gen)
    return cdc_encode_pallas(w_shards, gen, interpret=_interpret(),
                             **block_kw)


def cdc_decode(y_shards, parity, valid, *, use_pallas=True, **block_kw):
    if not use_pallas:
        return ref.cdc_decode_ref(y_shards, parity, valid)
    return cdc_decode_pallas(y_shards, parity, valid,
                             interpret=_interpret(), **block_kw)


def fused_head_argmax(x, w_shards, parity_w, valid, *, vocab,
                      use_pallas=True, **block_kw):
    """Fused coded LM-head GEMM + Eq. 12 parity decode + greedy argmax.

    The batched executor's decode hot path: one kernel per round, the
    merged [b, vocab] logits never hit HBM. Handles <= 1 erased shard.
    """
    if not use_pallas:
        return ref.fused_head_argmax_ref(x, w_shards, parity_w, valid, vocab)
    return cdc_fused_head_argmax_pallas(x, w_shards, parity_w, valid,
                                        vocab=vocab, interpret=_interpret(),
                                        **block_kw)


def rmsnorm(x, gamma, *, eps=1e-6, use_pallas=True, **block_kw):
    if not use_pallas:
        return ref.rmsnorm_ref(x, gamma, eps)
    return rmsnorm_pallas(x, gamma, eps=eps, interpret=_interpret(),
                          **block_kw)
