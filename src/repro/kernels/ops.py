"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; on this CPU container they execute in
``interpret=True`` mode (the kernel body runs in Python on CPU) so every test
and benchmark exercises the real kernel logic. ``use_pallas=False`` (or
backends where even interpret is undesirable for perf) falls back to the
ref oracle -- identical math, so the swap is safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.cdc_decode import (cdc_decode_pallas,
                                      cdc_fused_head_argmax_pallas)
from repro.kernels.cdc_encode import cdc_encode_pallas
from repro.kernels.cdc_matmul import (cdc_coded_matmul_pallas,
                                      cdc_decode_merge_pallas, eq12_plan)
from repro.kernels.matmul import matmul_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ------------------------------------------------------- kernel cost model ----
#
# On TPU a pallas_call lowers to an opaque ``custom-call`` whose HLO carries
# no dot ops, so ``roofline.hlo_cost.analyze_hlo`` would count ~0 FLOPs for
# the fused round (interpret mode on CPU inlines the kernel body into
# ordinary dots and needs none of this). Each kernel therefore registers a
# pure shape-based FLOP model keyed by its jitted wrapper name — the name
# appears verbatim in the custom-call's ``metadata={op_name=...}`` — and the
# analyzer adds the modelled FLOPs to that instruction. Bytes stay with the
# analyzer's generic operands+output accounting (the custom-call boundary IS
# the HBM round trip), so nothing is double-counted.
#
# Cost fns take (out_shapes, operand_shapes) — each a list of (dtype,
# [dims]) in instruction order — and return dot-equivalent FLOPs, matching
# what the inlined interpret-mode HLO reports for the same kernel.

def _elems(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _cost_matmul(out, operands):
    # x [m, k] @ w [k, n] -> [m, n]
    if not out or len(out[0][1]) != 2 or not operands:
        return 0.0
    m, n = out[0][1]
    k = operands[0][1][-1] if operands[0][1] else 0
    return 2.0 * m * n * k


def _cost_cdc_encode(out, operands):
    # parity [r, ...] = gen [r, T] @ shards: 2 * out_elems * T
    t = next((d[1] for _, d in operands if len(d) == 2), 0)
    return 2.0 * sum(_elems(d) for _, d in out) * t


def _cost_cdc_coded_matmul(out, operands):
    # operand order: [valid, esel, coef, gen, x, w_shards, parity_w, gamma?]
    # out [rows, T, m_l]; T+r shard GEMMs of x [rows, k] @ [k, m_l]
    if not out or len(out[0][1]) != 3:
        return 0.0
    rows, t, m_l = out[0][1]
    rank3 = [d for _, d in operands if len(d) == 3]
    if len(rank3) < 2:
        return 0.0
    k = rank3[0][1]            # w_shards [T, k, m_l]
    r = rank3[1][0]            # parity_w [r, k, m_l]
    return 2.0 * rows * k * m_l * (t + r)


def _cost_cdc_fused_head(out, operands):
    # operand order: [valid, x [b, k], w_shards [T, k, m_l], parity_w
    # [k, m_l]]; T shard GEMMs + 1 sum-parity GEMM of [b, k] @ [k, m_l]
    b = out[0][1][0] if out and out[0][1] else 0
    w = next((d for _, d in operands if len(d) == 3), None)
    if w is None:
        return 0.0
    t, k, m_l = w
    return 2.0 * b * k * m_l * (t + 1)


def _zero_cost(out, operands):
    # elementwise decode/normalize kernels: no dot FLOPs (consistent with
    # analyze_hlo counting only dot/convolution ops)
    return 0.0


#: jitted-wrapper name -> FLOP model; matched against custom-call lines by
#: LONGEST name containment (``matmul_pallas`` is a substring of
#: ``cdc_coded_matmul_pallas``).
KERNEL_COSTS: dict = {}


def register_kernel_cost(name: str, fn) -> None:
    """Register/overwrite the FLOP model for a Pallas kernel wrapper."""
    KERNEL_COSTS[name] = fn


for _name, _fn in (
        ("matmul_pallas", _cost_matmul),
        ("cdc_encode_pallas", _cost_cdc_encode),
        ("cdc_coded_matmul_pallas", _cost_cdc_coded_matmul),
        ("cdc_fused_head_argmax_pallas", _cost_cdc_fused_head),
        ("cdc_decode_merge_pallas", _zero_cost),
        ("cdc_decode_pallas", _zero_cost),
        ("rmsnorm_pallas", _zero_cost),
):
    register_kernel_cost(_name, _fn)


def _concrete_dead(valid) -> int | None:
    """Number of dead shards when the mask is host-concrete, else None.

    Traced masks (inside jit) cannot be counted at trace time — the
    <=1-erasure gate for the fused kernels then falls to the CALLER
    (``executor.vstep`` host-checks the mask before dispatching a fused
    round)."""
    if valid is None:
        return 0
    if isinstance(valid, jax.core.Tracer):
        return None
    v = np.asarray(valid)
    return int(v.size - v.sum())


def matmul(x, w, *, out_dtype=None, use_pallas=True, **block_kw):
    if not use_pallas:
        return ref.matmul_ref(x, w, out_dtype)
    return matmul_pallas(x, w, out_dtype=out_dtype, interpret=_interpret(),
                         **block_kw)


def cdc_encode(w_shards, gen, *, use_pallas=True, **block_kw):
    gen = jnp.asarray(gen, dtype=jnp.float32)
    if not use_pallas:
        return ref.cdc_encode_ref(w_shards, gen)
    return cdc_encode_pallas(w_shards, gen, interpret=_interpret(),
                             **block_kw)


def cdc_decode(y_shards, parity, valid, *, use_pallas=True, **block_kw):
    """r=1 Eq. 12 recovery combine; <=1 erased shard by construction.

    A host-concrete mask with 2+ erasures raises (a single sum parity
    cannot solve for two unknowns); the r>1 MDS layouts decode via
    ``core.coded_layer`` / ``fused_decode_merge`` instead.
    """
    dead = _concrete_dead(valid)
    if dead is not None and dead > 1:
        raise ValueError(
            f"cdc_decode is the r=1 Eq. 12 combine (one parity equation) "
            f"and recovers at most 1 erased shard, got {dead} dead")
    if not use_pallas:
        return ref.cdc_decode_ref(y_shards, parity, valid)
    return cdc_decode_pallas(y_shards, parity, valid,
                             interpret=_interpret(), **block_kw)


def fused_head_argmax(x, w_shards, parity_w, valid, *, vocab,
                      use_pallas=True, **block_kw):
    """Fused coded LM-head GEMM + Eq. 12 parity decode + greedy argmax.

    The batched executor's decode hot path: one kernel per round, the
    merged [b, vocab] logits never hit HBM. Handles <= 1 erased shard
    (both the kernel and the ref oracle consume only the SUM parity row):
    a host-concrete mask with 2+ erasures raises instead of silently
    decoding garbage — multi-erasure rounds belong to the reference MDS
    path, which ``executor.vstep`` selects before dispatch (traced masks
    are the caller's contract for the same reason, see _concrete_dead).
    """
    dead = _concrete_dead(valid)
    if dead is not None and dead > 1:
        raise ValueError(
            f"fused_head_argmax recovers at most 1 erased shard (Eq. 12 "
            f"sum-parity regime), got {dead} dead; use the reference "
            f"decode path (full logits + MDS recovery) for this round")
    if not use_pallas:
        return ref.fused_head_argmax_ref(x, w_shards, parity_w, valid, vocab)
    return cdc_fused_head_argmax_pallas(x, w_shards, parity_w, valid,
                                        vocab=vocab, interpret=_interpret(),
                                        **block_kw)


def fused_coded_matmul(x, w, w_cdc, spec, valid, *, valid_parity=None,
                       gamma=None, eps=1e-5, use_pallas=True,
                       out_dtype=None, **block_kw):
    """Fused in-body coded GEMM: (rmsnorm?) + T shard GEMMs + r parity
    GEMMs + Eq. 12 decode + merge in ONE kernel — per-shard outputs never
    round-trip HBM.

    x: [..., k]; w: [k, m] (column-sharded logical weight); w_cdc: parity
    weights in either layout (folded slots are unfolded host-side — the
    kernel always sees dedicated [r, k, m_l] parity). Returns the merged
    [..., m] activation, matching ``core.coded_matmul`` bit-close under
    every in-budget <=1-erasure mask.

    Fallback ladder (never a silent wrong answer):
      * host-concrete mask with 2+ dead  -> reference ``coded_matmul``
        (full MDS recovery, exact reference semantics);
      * traced mask -> kernel unconditionally; the caller must gate
        (vstep host-checks <=1 dead before dispatching a fused round);
      * ``use_pallas=False`` -> the ``ref.py`` oracle (same plan + math).
    """
    from repro.core import coded_layer
    code = spec.code
    T, r = code.n_shards, code.n_parity
    dead = _concrete_dead(valid)
    if w_cdc is None or r == 0 or valid is None \
            or (dead is not None and dead > 1):
        xn = ref.rmsnorm_ref(x, gamma, eps) if gamma is not None else x
        return coded_layer.coded_matmul(xn, w, w_cdc, spec, valid,
                                        valid_parity=valid_parity)
    valid = jnp.asarray(valid)
    if valid_parity is None:
        valid_parity = valid
    k, m = w.shape
    m_l = m // T
    w_st = jnp.moveaxis(w.reshape(k, T, m_l), 1, 0)        # [T, k, m_l]
    if spec.layout == "dedicated":
        pw = w_cdc                                         # [r, k, m_l]
    else:
        pw = coded_layer.unfold_parity(w_cdc, T, r)        # -> [r, k, m_l]
    gen = jnp.asarray(code.generator, jnp.float32)
    esel, coef = eq12_plan(spec, valid, valid_parity, m_l)
    lead = x.shape[:-1]
    xf = x.reshape(-1, k)
    if not use_pallas:
        out = ref.cdc_coded_matmul_ref(xf, w_st, pw, gen, esel, coef,
                                       valid, gamma=gamma, eps=eps,
                                       out_dtype=out_dtype)
    else:
        out = cdc_coded_matmul_pallas(xf, w_st, pw, gen, esel, coef, valid,
                                      gamma=gamma, eps=eps,
                                      out_dtype=out_dtype,
                                      interpret=_interpret(), **block_kw)
    return out.reshape(lead + (m,))


def fused_decode_merge(ys, parity, spec, valid, *, valid_parity=None,
                       use_pallas=True, out_dtype=None, **block_kw):
    """Fused Eq. 12 decode + merge of already-computed shard outputs —
    the ``core.decode_and_merge`` tail (e.g. outputs gathered by
    ``dist.collectives``) as one kernel pass.

    ys: [T, ..., m_l]; parity: dedicated [r, ..., m_l] or folded slots
    [T, ..., r*w] (unfolded host-side). Same <=1-erasure regime and
    fallback ladder as ``fused_coded_matmul``.
    """
    from repro.core import coded_layer
    code = spec.code
    T, r = code.n_shards, code.n_parity
    dead = _concrete_dead(valid)
    if parity is None or r == 0 or valid is None \
            or (dead is not None and dead > 1):
        return coded_layer.decode_and_merge(ys, parity, spec, valid,
                                            valid_parity=valid_parity)
    valid = jnp.asarray(valid)
    if valid_parity is None:
        valid_parity = valid
    m_l = ys.shape[-1]
    if spec.layout == "dedicated":
        par = parity                                       # [r, ..., m_l]
    else:
        par = coded_layer.unfold_parity(parity, T, r)      # -> [r, ..., m_l]
    gen = jnp.asarray(code.generator, jnp.float32)
    esel, coef = eq12_plan(spec, valid, valid_parity, m_l)
    mid = ys.shape[1:-1]
    ysf = ys.reshape(T, -1, m_l)
    parf = par.reshape(r, -1, m_l)
    if not use_pallas:
        out = ref.cdc_decode_merge_ref(ysf, parf, gen, esel, coef, valid,
                                       out_dtype=out_dtype)
    else:
        out = cdc_decode_merge_pallas(ysf, parf, gen, esel, coef, valid,
                                      out_dtype=out_dtype,
                                      interpret=_interpret(), **block_kw)
    return out.reshape(mid + (T * m_l,))


def rmsnorm(x, gamma, *, eps=1e-6, use_pallas=True, **block_kw):
    if not use_pallas:
        return ref.rmsnorm_ref(x, gamma, eps)
    return rmsnorm_pallas(x, gamma, eps=eps, interpret=_interpret(),
                          **block_kw)
