import os
# A caller-supplied device count (e.g. the 8-fake-device CI/test
# environment) wins; otherwise append enough host devices for the
# production meshes, preserving any unrelated pre-set XLA flags. Must
# precede any (transitive) jax import.
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = " ".join(filter(None, [
        os.environ.get("XLA_FLAGS"),
        "--xla_force_host_platform_device_count=512"]))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**input_specs).compile()`` must succeed on
the production meshes — (data=16, model=16) single-pod and
(pod=2, data=16, model=16) multi-pod — for every assigned architecture and
input shape. The compiled artifact also yields the roofline inputs
(cost_analysis + HLO collective bytes) recorded in EXPERIMENTS.md.

Resumable: results cache into a JSON file keyed by cell id; finished cells
are skipped. Run single cells with --arch/--shape/--mesh for iteration.

NOTE the XLA_FLAGS line above MUST stay the first statement — jax locks the
device count at first init. Do not import jax (even transitively) above it.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (SHAPES, ShapeSpec, all_archs,  # noqa: E402
                           get_arch, runnable, smoke_config)
from repro.dist.sharding import (batch_spec, param_specs,  # noqa: E402
                                 state_specs)
from repro.launch.mesh import (make_production_mesh,  # noqa: E402
                               make_test_mesh)
from repro.models import TPCtx, build  # noqa: E402
from repro.optim import AdamWConfig, init_state  # noqa: E402
from repro.roofline import roofline_report, roofline_terms  # noqa: E402
from repro.roofline.hlo_cost import analyze_hlo  # noqa: E402
from repro.train.train_step import TrainConfig, make_train_step  # noqa: E402

DEFAULT_OUT = "/root/repo/results/dryrun.json"

# --smoke: end-to-end proof on 8 fake host devices (CI / laptops). Same
# lower+compile pipeline, reduced configs, (2,4) / (2,2,2) test meshes.
SMOKE_SHAPES: dict[str, ShapeSpec] = {
    "train_smoke": ShapeSpec("train_smoke", 64, 8, "train"),
    "decode_smoke": ShapeSpec("decode_smoke", 128, 8, "decode"),
}


def count_params(params_shape, cfg) -> tuple[int, int]:
    """Exact (active, total) parameter census from the init eval_shape.

    Excludes parity leaves (redundant by construction) and the embedding
    table (lookup is not matmul FLOPs); MoE active = total minus the
    (1 - top_k/E) unrouted fraction of expert weights."""
    from jax.tree_util import tree_flatten_with_path

    def pname(path):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)

    total = active = 0
    e_pad = None
    for path, leaf in tree_flatten_with_path(params_shape)[0]:
        name = pname(path)
        n = 1
        for d in leaf.shape:
            n *= d
        if name.endswith("cdc") or name.split("/")[-1] == "embed":
            continue
        total += n
        if name.split("/")[-1] in ("we1", "we2", "we3"):
            e_pad = leaf.shape[-3] if leaf.ndim == 3 else leaf.shape[1]
            active += n * cfg.top_k / max(e_pad, 1)
        else:
            active += n
    return int(active), int(total)


def microbatches_for(cfg, shape, n_batch_devs: int = 16) -> int:
    """Grad-accum splits keeping per-device microbatch activations bounded
    (and the per-microbatch batch divisible by the batch-device count)."""
    if shape.kind != "train":
        return 1
    if cfg.d_model >= 8192 or cfg.n_layers >= 90:
        mb = 16
    elif cfg.d_model >= 4096:
        mb = 8
    else:
        mb = 4
    if cfg.n_experts:
        # §Perf H2b: each microbatch re-gathers the FSDP-sharded expert
        # weights per layer (fwd + remat'd bwd); fewer, fatter microbatches
        # trade activation memory for a ~mb-fold cut in gather wire bytes.
        mb = min(mb, 4)
    return min(mb, max(shape.global_batch // n_batch_devs, 1))


def input_specs(model, shape, mesh):
    """ShapeDtypeStruct stand-ins for the step inputs (no allocation)."""
    if shape.kind == "train":
        return model.input_spec(shape.global_batch, shape.seq_len)
    if shape.kind == "prefill":
        return model.input_spec(shape.global_batch, shape.seq_len)
    # decode: one new token against a seq_len cache
    return model.input_spec(shape.global_batch, 1)


def _shardings(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               coded: bool = False, code_r: int = 2, smoke: bool = False,
               verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = SMOKE_SHAPES.get(shape_name) or SHAPES[shape_name]
    ok, why = runnable(cfg, shape)
    if not ok:
        return {"status": "skip", "why": why}

    if smoke:
        cfg = smoke_config(cfg)
        mesh = make_test_mesh(2, 2, pod=2) if multi_pod \
            else make_test_mesh(2, 4)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    ctx = TPCtx(tp=tp, mode="coded" if coded else "plain", code_r=code_r,
                mesh=mesh)
    model = build(cfg, ctx)
    dtype = jnp.bfloat16

    t0 = time.time()
    params_shape = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0), dtype))
    p_spec = param_specs(params_shape, mesh)
    p_shard = _shardings(p_spec, mesh)
    n_batch_devs = mesh.shape.get("pod", 1) * mesh.shape["data"]
    gb = shape.global_batch
    tok_spec = batch_spec(mesh) if gb % n_batch_devs == 0 else P()
    tok_shard = NamedSharding(mesh, tok_spec)
    in_sds = input_specs(model, shape, mesh)

    # coded cells lower the RECOVERY math: the erasure mask is a runtime
    # input (all-true in the fault-free steady state), so the parity GEMMs
    # and the fused decode are part of the compiled step.
    valid_sds = jax.ShapeDtypeStruct((tp,), jnp.bool_) if coded else None
    valid_shard = NamedSharding(mesh, P()) if coded else None

    if shape.kind == "train":
        mb = microbatches_for(cfg, shape, n_batch_devs)
        tstep = make_train_step(model, AdamWConfig(),
                                TrainConfig(microbatches=mb, remat="full"))
        opt_shape = jax.eval_shape(lambda p: init_state(p), params_shape)
        o_spec = {"step": P(), "mu": p_spec, "nu": p_spec,
                  "master": p_spec}
        o_shard = _shardings(o_spec, mesh)
        batch_sh = {"tokens": tok_shard}
        if "frames" in in_sds:
            batch_sh["frames"] = NamedSharding(mesh, batch_spec(mesh))
        if coded:
            fn = jax.jit(lambda p, o, b, v: tstep(p, o, b, v),
                         in_shardings=(p_shard, o_shard, batch_sh,
                                       valid_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
            args = (params_shape, opt_shape, in_sds, valid_sds)
        else:
            fn = jax.jit(tstep,
                         in_shardings=(p_shard, o_shard, batch_sh),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
            args = (params_shape, opt_shape, in_sds)
    elif shape.kind == "prefill":
        def prefill_step(params, batch, valid=None):
            state = model.init_decode(params, batch, shape.global_batch,
                                      shape.seq_len, dtype, valid=valid)
            logits, state = model.decode(params, state, batch["tokens"],
                                         valid, last_only=True)
            return logits, state

        state_shape = jax.eval_shape(
            lambda p, b: model.init_decode(p, b, shape.global_batch,
                                           shape.seq_len, dtype),
            params_shape, in_sds)
        s_shard = _shardings(state_specs(state_shape, mesh), mesh)
        batch_sh = {"tokens": tok_shard}
        if "frames" in in_sds:
            batch_sh["frames"] = NamedSharding(mesh, batch_spec(mesh))
        if coded:
            fn = jax.jit(prefill_step,
                         in_shardings=(p_shard, batch_sh, valid_shard),
                         out_shardings=(None, s_shard))
            args = (params_shape, in_sds, valid_sds)
        else:
            fn = jax.jit(prefill_step,
                         in_shardings=(p_shard, batch_sh),
                         out_shardings=(None, s_shard))
            args = (params_shape, in_sds)
    else:  # decode
        # serving layout: weights replicated over `data` (fits comfortably:
        # params/TP <= ~1 GB/chip bf16) => zero weight-gather traffic/step.
        # MoE archs keep FSDP-sharded experts (replicating 100B+ of expert
        # weights per data shard would blow HBM; see EXPERIMENTS.md).
        if not cfg.n_experts:
            p_spec = param_specs(params_shape, mesh, fsdp=None)
            p_shard = _shardings(p_spec, mesh)
        state_shape = jax.eval_shape(
            lambda p, b: model.init_decode(p, b, shape.global_batch,
                                           shape.seq_len, dtype),
            params_shape,
            model.input_spec(shape.global_batch, shape.seq_len))
        s_spec = state_specs(state_shape, mesh)
        s_shard = _shardings(s_spec, mesh)

        def serve_step(params, state, tokens, valid=None):
            return model.decode(params, state, tokens, valid)

        if coded:
            fn = jax.jit(serve_step,
                         in_shardings=(p_shard, s_shard, tok_shard,
                                       valid_shard),
                         out_shardings=(None, s_shard),
                         donate_argnums=(1,))
            args = (params_shape, state_shape, in_sds["tokens"], valid_sds)
        else:
            fn = jax.jit(serve_step,
                         in_shardings=(p_shard, s_shard, tok_shard),
                         out_shardings=(None, s_shard),
                         donate_argnums=(1,))
            args = (params_shape, state_shape, in_sds["tokens"])

    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):  # older jax: one dict per device
        xla_cost = xla_cost[0] if xla_cost else None
    hlo = compiled.as_text()
    # trip-count-weighted analysis (XLA's cost_analysis counts loop bodies
    # once; see roofline/hlo_cost.py)
    wcost = analyze_hlo(hlo)
    coll = {"total": wcost["wire_bytes"], "counts":
            wcost["collective_counts"], **wcost["wire_by_kind"]}

    # roofline
    terms = roofline_terms({"flops": wcost["flops"],
                            "bytes accessed": wcost["bytes"]}, coll)
    chips = mesh.size
    n_active, n_total = count_params(params_shape, cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens / chips
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens / chips
    else:
        tokens = shape.global_batch  # one token per sequence
        model_flops = 2 * n_active * tokens / chips
    report = roofline_report(terms, model_flops)

    mem_fields = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_fields[f] = getattr(mem, f, None)

    mesh_label = "x".join(str(s) for s in mesh.devices.shape)
    rec = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": ("pod" + mesh_label) if multi_pod else mesh_label,
        "coded": coded,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_fields,
        "cost": {"flops": wcost["flops"], "bytes": wcost["bytes"],
                 "xla_flops_unweighted":
                     xla_cost.get("flops") if xla_cost else None},
        "params": {"total": n_total, "active": n_active},
        "collectives": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll["counts"],
        "roofline": {k: report[k] for k in
                     ("compute_s", "memory_s", "collective_s", "dominant",
                      "useful_ratio", "roofline_fraction", "model_flops")},
    }
    if verbose:
        print(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + list(SMOKE_SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--coded", action="store_true")
    ap.add_argument("--code-r", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="8-device end-to-end proof: smoke configs on the "
                         "(2,4)/(2,2,2) test meshes, smoke shapes")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        archs = [args.arch] if args.arch else (
            sorted(all_archs()) if args.all else ["granite-3-8b"])
        shapes = [args.shape] if args.shape else list(SMOKE_SHAPES)
    else:
        archs = [args.arch] if args.arch else sorted(all_archs())
        shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    run_keys = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}" + \
                    ("|coded" if args.coded else "") + \
                    ("|smoke" if args.smoke else "")
                run_keys.append(key)
                if key in results and results[key].get("status") in \
                        ("ok", "skip"):
                    print(f"[cached] {key}")
                    continue
                print(f"[lower+compile] {key}", flush=True)
                try:
                    rec = lower_cell(arch, shape, multi_pod=mp,
                                     coded=args.coded, code_r=args.code_r,
                                     smoke=args.smoke, verbose=False)
                except Exception as e:  # record the failure, keep going
                    rec = {"status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    print(rec["trace"])
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, default=str)
                print(f"  -> {rec['status']} "
                      f"(compile {rec.get('compile_s', '-')}s, "
                      f"dominant {rec.get('roofline', {}).get('dominant')})",
                      flush=True)

    # status over THIS run's grid only — a reused --out file may hold
    # stale cells from other sweeps that were neither run nor retried
    run = [results[k] for k in run_keys]
    n_ok = sum(1 for r in run if r["status"] == "ok")
    n_skip = sum(1 for r in run if r["status"] == "skip")
    n_err = sum(1 for r in run if r["status"] == "error")
    print(f"done: {n_ok} ok, {n_skip} structured skips, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
