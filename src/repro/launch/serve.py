"""Serving driver: runtime-scheduled generation with CDC fault injection.

Drives the coded cluster runtime (``repro.runtime``): requests are
submitted to the continuous-batching scheduler — the BATCHED slot
executor advances every decode slot in one jitted dispatch per round for
EVERY zoo architecture (enc-dec requests carry per-request encoder
frames into the stacked extras bank; xLSTM stacks its positionless block
state) — and a shard erasure can be injected at a simulated time; within
the code's budget the runtime recovers in-step, beyond it the CDC+2MR
hybrid requeues and heals.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --smoke \\
      --coded --fail-time-ms 4 --fail-shard 2
  PYTHONPATH=src python -m repro.launch.serve --arch whisper-medium \\
      --smoke --coded --fail-time-ms 4 --fail-shard 2

``--sequential`` keeps the per-slot stepping alive as the test oracle /
escape hatch (it is no longer the production path for any family),
``--no-overlap`` disables host/device round pipelining, ``--deadline-ms``
and ``--max-queue-depth`` exercise the SLO admission queue. ``--legacy``
runs the old one-batch-at-a-time ServingEngine path with the original
--fail-step semantics.

Chaos mode (``repro.faults``): ``--chaos <spec|trace>`` drives the health
controller with a seeded churn process (e.g.
``--chaos "weibull:mtbf=2000,mttr=120"`` — scale MTBF against the ~50 ms
modelled round floor) or a JSONL trace file, with the modelled round
latency following the same fault schedule; ``--adapt-r`` closes the loop
with the adaptive redundancy planner (re-sizes r through heal + parity
re-encode to hold ``--avail-target``). ``--seed`` is the root seed: the
whole chaos run replays bit-exact.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --smoke \\
      --coded --chaos "exp:mtbf=800,mttr=120" --adapt-r
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core.failure import StragglerModel
from repro.faults import (AdaptiveRedundancyPlanner, InjectedLatency,
                          LatencySpec, PlannerConfig, attach_chaos,
                          attach_planner, measured_stall_hook, parse_chaos)
from repro.models import TPCtx, build
from repro.obs import (FlightRecorder, MetricsServer, validate_chrome_trace,
                       write_chrome_trace)
from repro.runtime import (ContinuousBatchingScheduler, RuntimeConfig,
                           ShardHealthController, erasure, run_arrivals)
from repro.serve import ModelStepper, ServeConfig, ServingEngine


def _legacy(args, model, params):
    eng = ServingEngine(model, params,
                        ServeConfig(max_len=args.prompt_len
                                    + args.gen_tokens + 8, batch=args.batch,
                                    cache_dtype=jnp.float32))
    batch = model.dummy_batch(jax.random.PRNGKey(1), args.batch,
                              args.prompt_len)
    fail_at = {args.fail_step: args.fail_shard} if args.fail_step >= 0 \
        else None
    toks = eng.generate(batch, args.gen_tokens, fail_at=fail_at)
    print("generated tokens (first sequence):", toks[0].tolist())
    print("engine metrics:", eng.metrics)
    if args.coded:
        print("straggler model (first-T-of-T+r):",
              eng.straggler_latency(StragglerModel(), n_trials=5000))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--coded", action="store_true")
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2,
                    help="runtime: decode slots; legacy: batch size")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--arrival-gap-ms", type=float, default=2.0)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--fail-time-ms", type=float, default=-1.0,
                    help="inject a shard erasure at this simulated time")
    ap.add_argument("--fail-shard", type=int, default=1)
    ap.add_argument("--fail-step", type=int, default=-1,
                    help="legacy mode: decode step to kill the shard at")
    ap.add_argument("--legacy", action="store_true")
    ap.add_argument("--sequential", action="store_true",
                    help="oracle-only per-slot stepping (one dispatch per "
                         "slot) instead of the batched executor; every "
                         "family — enc-dec and xLSTM included — batches "
                         "by default")
    ap.add_argument("--no-overlap", action="store_true",
                    help="harvest each round synchronously (no pipelining)")
    ap.add_argument("--fused", action="store_true",
                    help="force the full-Pallas round: fused in-body coded "
                         "GEMM+decode kernels and the fused head (interpret "
                         "off-TPU; default auto = native TPU only)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLO deadline after arrival")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="shed requests beyond this queue depth")
    ap.add_argument("--chaos", default=None, metavar="SPEC|TRACE",
                    help="fault injection: churn spec "
                         "('weibull:mtbf=2000,mttr=120,groups=2,"
                         "burst_mtbf=4000') or a JSONL trace path")
    ap.add_argument("--adapt-r", action="store_true",
                    help="adaptive redundancy planner: re-size r from "
                         "observed failures (heal + parity re-encode)")
    ap.add_argument("--avail-target", type=float, default=0.999,
                    help="planner availability target")
    ap.add_argument("--plan-window-ms", type=float, default=300.0,
                    help="planner estimation window (sim time; several "
                         "decode rounds, ~50 ms each under the default "
                         "straggler floor)")
    ap.add_argument("--seed", type=int, default=0,
                    help="root seed: stragglers, injector, and injected "
                         "latency all derive from it (bit-exact replay)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the flight recorder and write a "
                         "Perfetto/Chrome trace_event JSON (open it at "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve live Prometheus text metrics at "
                         "/metrics (and the trace at /trace) on this "
                         "port; 0 binds an ephemeral port")
    ap.add_argument("--slo-report", action="store_true",
                    help="print the per-request SLO breakdown after the "
                         "run: p50/p99 TTFT/TPOT decomposition tables and "
                         "deadline-miss attribution (same renderer as "
                         "python -m repro.obs.slo report)")
    ap.add_argument("--perf", action="store_true",
                    help="roofline-anchored round attribution: useful vs "
                         "parity FLOPs, live coded_overhead_frac, achieved "
                         "vs roofline utilization (auto-enabled with "
                         "--trace/--metrics-port/--profile)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the run into DIR "
                         "(rounds annotated as decode_round steps; open "
                         "with TensorBoard or Perfetto)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    ctx = TPCtx(tp=args.tp, mode="coded" if args.coded else "plain",
                moe_capacity=0)
    model = build(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    if args.legacy or args.fail_step >= 0:
        return _legacy(args, model, params)

    stepper = ModelStepper(model, params,
                           max_len=args.prompt_len + args.gen_tokens + 8)
    events = [erasure(args.fail_time_ms, args.fail_shard)] \
        if args.fail_time_ms >= 0 else []
    health = ShardHealthController(stepper.n_shards, stepper.erasure_budget,
                                   events=events)
    # perf accounting rides along whenever any observability sink is on:
    # the counter track needs it for --trace, the gauges for --metrics-port
    perf = bool(args.perf or args.trace or args.metrics_port is not None
                or args.profile)
    rcfg = RuntimeConfig(n_slots=args.batch,
                         batched=False if args.sequential else None,
                         overlap=not args.no_overlap,
                         use_fused=True if args.fused else "auto",
                         max_queue_depth=args.max_queue_depth,
                         seed=args.seed, perf=perf,
                         profile=args.profile is not None)
    injector = latency = None
    if args.chaos:
        injector = parse_chaos(args.chaos, stepper.n_shards, seed=args.seed)
        latency = InjectedLatency(LatencySpec(), injector, seed=args.seed)
    tracer = FlightRecorder() \
        if args.trace or args.metrics_port is not None else None
    sched = ContinuousBatchingScheduler(stepper, rcfg, health=health,
                                        latency=latency, tracer=tracer)
    server = None
    if args.metrics_port is not None:
        server = MetricsServer(sched.metrics, sched.shardlog, tracer,
                               sched.clock, port=args.metrics_port,
                               spans=sched.spans).start()
        print(f"metrics: http://127.0.0.1:{server.port}/metrics "
              f"(live trace: /trace)")
    if injector is not None:
        attach_chaos(sched, injector)
        if sched.executor is not None:
            sched.executor.round_hooks.append(measured_stall_hook(latency))
    if args.adapt_r:
        planner = AdaptiveRedundancyPlanner(
            PlannerConfig(target_availability=args.avail_target,
                          window_ms=args.plan_window_ms),
            stepper.n_shards, layout=model.ctx.code_layout,
            suitable=stepper.erasure_budget > 0 or not args.coded)
        attach_planner(sched, planner)
    rng = np.random.default_rng(1)

    def extras():
        # enc-dec: per-request encoder frames (frontend stub) — threaded
        # into the executor's stacked extras bank at admission
        if not cfg.is_encdec:
            return None
        return {"frames": rng.normal(
            size=(cfg.enc_seq, cfg.d_model)).astype(np.float32)}

    if args.profile:
        jax.profiler.start_trace(args.profile)
    if args.deadline_ms is not None:
        for i in range(args.requests):
            t = i * args.arrival_gap_ms
            sched.submit(rng.integers(0, cfg.vocab, args.prompt_len),
                         args.gen_tokens, arrival_ms=None,
                         deadline_ms=t + args.deadline_ms,
                         extras=extras())
        completed = sched.run()
    else:
        arrivals = [(i * args.arrival_gap_ms,
                     rng.integers(0, cfg.vocab, args.prompt_len),
                     args.gen_tokens, extras()) for i in range(args.requests)]
        completed = run_arrivals(sched, arrivals)
    if args.profile:
        jax.profiler.stop_trace()
        print(f"profile: wrote jax.profiler trace to {args.profile}")
    mode = "sequential" if sched.executor is None else \
        ("batched+overlap" if rcfg.overlap else "batched")
    print(f"completed {len(completed)}/{args.requests} requests "
          f"({mode}; shed {len(sched.shed)})")
    if completed:
        print("tokens (first request):", completed[0].tokens)
    if sched.executor is not None:
        print(f"executor: {sched.executor.vstep.n_dispatches} round "
              f"dispatches, {sched.executor.vstep.n_traces} trace(s)")
        if sched.executor.perf is not None \
                and sched.executor.perf.n_observed:
            s = sched.executor.perf.summary()
            print(f"perf: {s['model_flops'] / 1e6:.2f} MFLOP useful/round "
                  f"({s['coded_overhead_frac']:.3f} coded overhead, "
                  f"{s['parity_device_equiv']:.3f} parity device-equiv), "
                  f"{s['achieved_flops_per_s'] / 1e9:.2f} GFLOP/s achieved, "
                  f"{s['hbm_gbs']:.2f} GB/s, roofline utilization "
                  f"{s['roofline_utilization']:.4f} ({s['dominant']}-bound)")
    if injector is not None:
        c = sched.metrics.counters
        print(f"chaos: {c['faults_injected']} injected events, "
              f"{c['erasures_recovered']} recovered in-step, "
              f"{c['beyond_budget_failures']} beyond budget")
    if args.adapt_r and sched.metrics.plan_log:
        series = [(p["t_ms"], p["r"]) for p in sched.metrics.plan_log]
        print(f"planner: r series {series} "
              f"(replans: {sched.metrics.counters['replans']})")
    if args.slo_report and sched.spans is not None:
        from repro.obs.slo import decompositions, render_report
        print("--- slo report " + "-" * 49)
        print(render_report(decompositions(sched.spans)))
        print("-" * 64)
    if args.trace:
        trace = write_chrome_trace(
            args.trace, tracer, sched.shardlog, now_ms=sched.clock.now(),
            meta={"arch": args.arch, "seed": args.seed,
                  "chaos": args.chaos or "", "adapt_r": args.adapt_r},
            spans=sched.spans)
        stats = validate_chrome_trace(
            trace, require_span_closure=sched.spans is not None
            and len(sched.spans.done) > 0)
        print(f"trace: wrote {args.trace} ({stats['n_events']} events on "
              f"{stats['n_tracks']} tracks; "
              f"{stats['n_injected_erasures']} injected erasures, all "
              f"linked to a resolution; {stats['n_span_trees']} request "
              f"span trees closed and gap-accounted)")
    if server is not None:
        server.stop()
    print(sched.metrics.to_json())
    if args.coded:
        print("straggler model (first-T-of-T+r):",
              stepper.straggler_latency(StragglerModel(), n_trials=5000))


if __name__ == "__main__":
    main()
