"""Serving driver: batched generation with CDC fault injection.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-8b --smoke \\
      --coded --fail-step 4 --fail-shard 2
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core.failure import StragglerModel
from repro.models import TPCtx, build
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--coded", action="store_true")
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--fail-step", type=int, default=-1)
    ap.add_argument("--fail-shard", type=int, default=1)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    ctx = TPCtx(tp=args.tp, mode="coded" if args.coded else "plain",
                moe_capacity=0)
    model = build(cfg, ctx)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        ServeConfig(max_len=args.prompt_len
                                    + args.gen_tokens + 8, batch=args.batch,
                                    cache_dtype=jnp.float32))
    batch = model.dummy_batch(jax.random.PRNGKey(1), args.batch,
                              args.prompt_len)
    fail_at = {args.fail_step: args.fail_shard} if args.fail_step >= 0 \
        else None
    toks = eng.generate(batch, args.gen_tokens, fail_at=fail_at)
    print("generated tokens (first sequence):", toks[0].tolist())
    print("engine metrics:", eng.metrics)
    if args.coded:
        print("straggler model (first-T-of-T+r):",
              eng.straggler_latency(StragglerModel(), n_trials=5000))


if __name__ == "__main__":
    main()
