"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b --smoke \\
      --steps 200 --coded

On this CPU container --smoke swaps in the reduced config; on a real fleet
the full config + production mesh apply (the dry-run proves those lower).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch, smoke_config
from repro.data import DataConfig
from repro.models import TPCtx, build
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--coded", action="store_true",
                    help="CDC-coded TP (the paper's technique)")
    ap.add_argument("--tp", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    ctx = TPCtx(tp=args.tp if args.coded else 1,
                mode="coded" if args.coded else "plain")
    model = build(cfg, ctx)
    trainer = Trainer(
        model,
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(args.steps // 4, 10), log_every=5),
        AdamWConfig(lr=args.lr, total_steps=args.steps, warmup_steps=10),
        TrainConfig(microbatches=args.microbatches,
                    remat="none" if args.smoke else "full"),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch),
    )
    out = trainer.run(resume=not args.no_resume)
    print("step,loss")
    for step, loss in out["losses"]:
        print(f"{step},{loss:.4f}")
    print(f"# wall: {out['wall_s']:.1f}s  arch={cfg.name} coded={args.coded}")


if __name__ == "__main__":
    main()
