"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices before first jax init, while tests/benches run on 1 CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4, pod: int | None = None):
    """Small host-device meshes for subprocess tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
