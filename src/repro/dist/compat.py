"""Version-tolerant shard_map.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` to the top-level
namespace (and renamed ``check_rep`` -> ``check_vma``) across 0.4.x/0.5.x.
This repo's distributed paths always want the unchecked variant (they use
``axis_index`` / ``ppermute`` freely), so expose one ``shard_map(f, mesh,
in_specs, out_specs)`` that resolves whichever API the installed jax has.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def _resolve():
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    return fn


def shard_map(f, mesh, in_specs, out_specs):
    fn = _resolve()
    for kw in ("check_vma", "check_rep"):
        try:
            return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{kw: False})
        except TypeError:
            continue
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
