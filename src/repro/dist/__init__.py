"""repro.dist — GSPMD + shard_map distribution layer.

  sharding     param/state/batch PartitionSpec rules (mesh layout contract)
  collectives  coded_matmul_shardmap: explicit per-device coded GEMM whose
               parity decode crosses the `model` axis (all_gather + local
               subtract — the paper's master/worker message flow)
  pipeline     pipeline_apply: GPipe microbatching over the `pod` axis
  compat       shard_map shim across jax API generations
"""
from repro.dist.collectives import coded_matmul_shardmap
from repro.dist.pipeline import pipeline_apply
from repro.dist.sharding import (batch_axes, batch_spec, param_shardings,
                                 param_specs, state_specs)

__all__ = [
    "batch_axes",
    "batch_spec",
    "coded_matmul_shardmap",
    "param_shardings",
    "param_specs",
    "pipeline_apply",
    "state_specs",
]
