"""Explicit per-device coded GEMM via shard_map (DESIGN.md §4).

``core.coded_matmul`` expresses the paper's coded output-split GEMM as
logical stacked einsums and lets GSPMD place them. This module is the
explicit counterpart: ``coded_matmul_shardmap`` pins shard ↔ device — model
rank i holds weight columns [i*m_l, (i+1)*m_l) and (folded layout) parity
slot i — runs the per-device GEMMs locally, crosses the `model` axis with an
``all_gather`` of the T shard outputs (+ parity messages), and reruns the
exact single-device recovery (``core.decode_and_merge``) on every rank. A
dead device's contribution is what the erasure mask says it is: the rank's
column block and its folded parity slices, zeroed before decode.

This is the placement the paper measures (§6: each worker owns one weight
split; the master gathers T-of-(T+r) messages and locally subtracts), so the
multi-device tests validate real message loss rather than a simulated mask
on one device.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.coded_layer import (CodedDenseSpec, decode_and_merge,
                                    merge_shards)
from repro.dist.compat import shard_map
from repro.dist.sharding import batch_axes

__all__ = ["coded_matmul_shardmap"]


def coded_matmul_shardmap(
    x: jax.Array,
    w: jax.Array,
    w_cdc: jax.Array | None,
    spec: CodedDenseSpec,
    valid: jax.Array | None = None,
    *,
    mesh,
    axis: str = "model",
    valid_parity: jax.Array | None = None,
    acc_dtype=jnp.float32,
) -> jax.Array:
    """shard_map twin of ``core.coded_matmul`` (same signature + mesh).

    x: [..., k] activations; leading dim is additionally split over the
    pod/data axes when divisible. w: [k, m] with m = T * m_l; requires
    ``mesh.shape[axis] == T`` so shard i is physically model-rank i.
    Returns the merged [..., m], equal to ``x @ w`` under <= budget erasures.
    """
    code = spec.code
    T = code.n_shards
    if axis not in mesh.axis_names or mesh.shape[axis] != T:
        raise ValueError(
            f"mesh axis {axis!r} must exist with size T={T}, got "
            f"{dict(mesh.shape)}")
    k, m = w.shape
    if m % T:
        raise ValueError(f"output dim {m} not divisible by T={T}")

    coded = w_cdc is not None and code.n_parity > 0 and valid is not None
    folded = coded and spec.layout == "folded"
    if coded and valid_parity is None:
        valid_parity = valid

    # batch sharding of the activations over the non-model axes
    b_axes = tuple(a for a in batch_axes(mesh) if a != axis)
    n_b = 1
    for a in b_axes:
        n_b *= mesh.shape[a]
    if x.ndim < 2 or n_b <= 1 or x.shape[0] % n_b:
        b_axes = ()
    x_spec = P(*((b_axes if b_axes else None,)
                 + (None,) * (x.ndim - 1)))

    def local(xb, wb, cb, v, vp):
        # wb: [1, k, m_l] this rank's weight-column block
        y_i = xb @ wb[0]                                # [..., m_l]
        ys = jax.lax.all_gather(y_i, axis)              # [T, ..., m_l]
        if not coded:
            return merge_shards(ys)
        if folded:
            p_i = xb @ cb[0]                            # [..., r*w] my slot
            parity = jax.lax.all_gather(p_i, axis)      # [T, ..., r*w]
        else:
            # dedicated parity: the +r parity workers live off this mesh
            # axis; every rank re-derives their messages locally (cheap:
            # r/T of the data GEMM) instead of dedicating ranks.
            parity = jnp.einsum("...k,rkc->r...c", xb, cb,
                                preferred_element_type=xb.dtype)
        return decode_and_merge(ys, parity, spec, v, valid_parity=vp,
                                acc_dtype=acc_dtype)

    m_l = m // T
    w_blocked = jnp.moveaxis(w.reshape(k, T, m_l), 1, 0)  # [T, k, m_l]
    in_specs = [x_spec, P(axis, None, None)]
    args = [x, w_blocked]
    if coded:
        in_specs.append(P(axis, None, None) if folded else P(None, None,
                                                             None))
        args += [w_cdc, valid, valid_parity]
        in_specs += [P(None), P(None)]
        fn = shard_map(local, mesh, tuple(in_specs), x_spec)
        return fn(*args)

    fn = shard_map(lambda xb, wb: local(xb, wb, None, None, None), mesh,
                   tuple(in_specs), x_spec)
    return fn(x, w_blocked)
