"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

A stacked-layer model ([L, ...] params driven layer-by-layer) is cut into
S = mesh.shape["pod"] contiguous stages of L/S layers. The batch splits into
microbatches; each tick every stage applies its layers to its current
microbatch and ``ppermute``s the activation to the next stage, so after the
S-1-tick fill the pipeline runs all stages concurrently (bubble fraction
(S-1)/(n_microbatches + S - 1), the GPipe schedule). The batch dim inside a
microbatch additionally shards over ``data``.

This composes with the CDC layers: a stage's layer fn can itself run coded
GEMMs over the `model` axis of a (pod, data, model) mesh — erasure recovery
is intra-stage and never crosses the pipeline axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map

__all__ = ["pipeline_apply"]


def _seq_apply(layer, params, x):
    def body(h, p):
        return layer(p, h), None

    y, _ = jax.lax.scan(body, x, params)
    return y


def pipeline_apply(layer, params, x, *, mesh, n_microbatches: int = 4,
                   axis: str = "pod"):
    """Run ``x`` through L stacked layers, pipelined over ``axis``.

    layer:  fn(layer_params, h) -> h for ONE layer (params without the L dim)
    params: pytree with leading [L, ...] on every leaf
    x:      [B, ...] activations; B % n_microbatches == 0
    Returns [B, ...], numerically the sequential layer-by-layer result.
    """
    L = jax.tree.leaves(params)[0].shape[0]
    if axis not in mesh.axis_names or mesh.shape[axis] == 1:
        return _seq_apply(layer, params, x)  # no pipeline axis: sequential
    S = mesh.shape[axis]
    if L % S:
        raise ValueError(f"n_layers {L} not divisible by {S} stages")
    B = x.shape[0]
    n_mb = n_microbatches
    if B % n_mb:
        raise ValueError(f"batch {B} not divisible by {n_mb} microbatches")
    mb = B // n_mb

    # stage-blocked params [S, L/S, ...] and microbatched input [n_mb, mb, .]
    p_blocked = jax.tree.map(
        lambda a: a.reshape((S, L // S) + a.shape[1:]), params)
    x_mb = x.reshape((n_mb, mb) + x.shape[1:])

    data_ax = "data" if "data" in mesh.axis_names \
        and mb % mesh.shape["data"] == 0 else None
    x_spec = P(*((None, data_ax) + (None,) * (x.ndim - 1)))
    p_spec = jax.tree.map(
        lambda a: P(*((axis,) + (None,) * (a.ndim - 1))), p_blocked)

    def stage_fn(p_stage, x_loc):
        # p_stage leaves: [1, L/S, ...] (this stage's block); x_loc:
        # [n_mb, mb_loc, ...] the full microbatch queue (stage 0 reads it)
        p_stage = jax.tree.map(lambda a: a[0], p_stage)
        stage = jax.lax.axis_index(axis)
        fwd = [(i, (i + 1) % S) for i in range(S)]
        out0 = jnp.zeros(x_loc.shape, x_loc.dtype)
        recv0 = jnp.zeros(x_loc.shape[1:], x_loc.dtype)

        def tick(carry, t):
            out, recv = carry
            inp = jnp.where(stage == 0,
                            x_loc[jnp.clip(t, 0, n_mb - 1)], recv)
            y = _seq_apply(layer, p_stage, inp)
            oidx = jnp.clip(t - (S - 1), 0, n_mb - 1)
            write = (stage == S - 1) & (t >= S - 1)
            out = out.at[oidx].set(jnp.where(write, y, out[oidx]))
            recv = jax.lax.ppermute(y, axis, fwd)
            return (out, recv), None

        (out, _), _ = jax.lax.scan(tick, (out0, recv0),
                                   jnp.arange(n_mb + S - 1))
        # results live on the last stage; zero elsewhere + psum = broadcast
        return jax.lax.psum(jnp.where(stage == S - 1, out, 0), axis)

    fn = shard_map(stage_fn, mesh, (p_spec, x_spec), x_spec)
    y_mb = fn(p_blocked, x_mb)
    return y_mb.reshape((B,) + y_mb.shape[2:])
