"""GSPMD layout rules: map every model-zoo param/state pytree onto a mesh.

Mesh axes (DESIGN.md §4):
  ``model``  the tensor-parallel axis. Its size IS the code's T: coded GEMM
             output shard i (columns [i*m_l, (i+1)*m_l) of ``w``) and folded
             parity slot i both live on model-rank i, so a CDC shard maps to
             a real device placement and ``valid[i]`` names physical rank i.
  ``data``   batch/FSDP axis (weights sharded over it when ``fsdp="data"``).
  ``pod``    optional outer axis: extra batch parallelism for train/serve,
             and the stage axis for ``dist.pipeline``.

Everything here is pure layout metadata — functions take pytrees of arrays
or ShapeDtypeStructs and return matching pytrees of ``PartitionSpec`` /
``NamedSharding``. A dimension is only sharded when the axis exists in the
mesh AND divides it evenly; otherwise that dim falls back to replicated, so
the specs are total over every (arch x mesh) cell including ragged smoke
shapes.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

__all__ = ["param_specs", "param_shardings", "state_specs", "batch_spec",
           "batch_axes"]

# parent-dict names of row-parallel (input-split) GEMMs: first dim over
# `model` (megatron row layout; never coded — paper Table 1)
_ROW_PARALLEL = frozenset({"wo", "w2", "down", "out_proj"})
# stacked-layer containers (leaves carry a leading scan/vmap L axis)
_STACKED = frozenset({"layers", "enc_layers", "dec_layers"})
# MoE expert slabs [E, ., .]: expert axis over `model` (expert parallelism)
_EXPERT = frozenset({"we1", "we2", "we3"})


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes carrying the batch dimension, outermost first."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh) -> P:
    """Spec for [B, ...] batch inputs (tokens/frames): B over pod+data."""
    axes = batch_axes(mesh)
    return P(axes) if axes else P()


def _axis_size(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fit(spec: tuple, shape: tuple, mesh) -> P:
    """Drop any axis that is absent from the mesh or does not divide its
    dim; pad/trim the spec to the leaf's rank."""
    spec = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, axis in zip(shape, spec[:len(shape)]):
        if axis is None:
            out.append(None)
            continue
        names = axis if isinstance(axis, tuple) else (axis,)
        if all(a in mesh.axis_names for a in names) \
                and dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            names.append(f"#{k.idx}")
        else:
            names.append(str(k))
    return names


def _param_rule(names: list[str], shape: tuple, mesh, fsdp):
    """Base spec (before the stacked-L prefix) for one param leaf."""
    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    tp = mesh.shape["model"] if "model" in mesh.axis_names else 1

    if name == "w":
        if parent == "router":
            return ()                       # replicated (routing is local)
        if parent in _ROW_PARALLEL:
            return ("model", fsdp)          # [k, m]: input dim sharded
        return (fsdp, "model")              # column-parallel: T output shards
    if name == "cdc":
        # folded parity slots [T, k, r*w]: slot axis over `model` so slot d
        # rides on the same device as data shard d (whole-device failure
        # erases exactly its own slices). dedicated parity [r, k, m_l]:
        # shard the parity columns instead (the +r devices live off-mesh).
        # The layouts are told apart by the leading dim (T vs r); when they
        # collide (dedicated with r == T — full duplication, outside the
        # paper's r << T regime) the folded placement wins. Placement only:
        # GSPMD numerics are identical either way.
        if len(shape) >= 3 and shape[-3] == tp:
            return ("model", fsdp, None)
        return (None, fsdp, "model")
    if name == "embed":
        return ("model", fsdp)              # vocab rows over `model`
    if name in _EXPERT:
        return ("model", fsdp, None)        # EP: expert slab per rank
    return ()                               # norms, biases, scalars, ...


def param_specs(params, mesh, *, fsdp: str | None = "data"):
    """PartitionSpec pytree for a model param pytree (arrays or shape
    structs). ``fsdp=None`` replicates weights over the data axis (serving
    layout)."""

    def rule(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        stacked = any(n in _STACKED for n in names)
        base = _param_rule(names, shape[1:] if stacked else shape, mesh,
                           fsdp)
        if stacked:
            base = (None,) + tuple(base)
        return _fit(base, shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, params)


def param_shardings(params, mesh, *, fsdp: str | None = "data"):
    """NamedSharding pytree ready for ``jax.device_put`` / ``in_shardings``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, fsdp=fsdp),
                        is_leaf=lambda x: isinstance(x, P))


def state_specs(state, mesh):
    """Decode-state layout: batch dim over pod+data, bookkeeping replicated.

    KV caches / SSM states under scan-stacked containers carry a leading L
    axis (batch is dim 1); xLSTM's per-block list states put batch at dim 0.
    ``len``/``pos`` counters are replicated.
    """
    axes = batch_axes(mesh)

    def rule(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        if not axes or names[-1] in ("len", "pos") or len(shape) < 2:
            return P()
        b_dim = 0 if names[0] == "blocks" else 1
        spec = [None] * len(shape)
        spec[b_dim] = axes
        return _fit(tuple(spec), shape, mesh)

    return jax.tree_util.tree_map_with_path(rule, state)
