from repro.serve.engine import ModelStepper, ServeConfig, ServingEngine

