"""Serving engine: batched prefill/decode with CDC-coded fault tolerance.

This is where the paper's operational claims live at datacenter scale:

  * coded inference: every column-parallel GEMM carries parity shards; the
    engine feeds the CURRENT validity mask into each step, so a shard loss
    mid-request is recovered inside the same XLA program (close-to-zero
    recovery: no re-dispatch, no weight reload, no recompute — paper §5.2).
  * request continuity: "our solution never loses a request" — erasures
    flip the mask, the step still returns correct tokens; the engine also
    re-queues requests on whole-replica failures (the CDC+2MR hybrid, §6.3).
  * straggler mitigation (§6.2): with r parity shards the combiner
    semantically needs any T of T+r shard messages. A synchronous TPU mesh
    can't skip laggards inside a step, so the engine exposes the paper's
    first-T-of-(T+r) latency model for the pod/DCN boundary, simulated with
    the measured per-shard latency distribution (core.failure).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.failure import StragglerModel, request_latency
from repro.models.zoo import Model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 2048
    batch: int = 8
    cache_dtype: Any = jnp.float32
    greedy: bool = True


class ServingEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig):
        self.model = model
        self.scfg = scfg
        self.params = model.encode_offline(params)
        T = model.ctx.tp
        self.valid = jnp.ones(max(T, 1), bool)
        self._decode = jax.jit(
            lambda p, st, tok, valid: model.decode(p, st, tok, valid))
        self.metrics = {"requests": 0, "erasures_recovered": 0,
                        "requeued": 0}

    # -------------------------------------------------------- failures ----
    def inject_failure(self, shard: int):
        """Mark a TP shard dead. Subsequent steps recover via parity."""
        self.valid = self.valid.at[shard].set(False)
        self.metrics["erasures_recovered"] += 1

    def heal(self, shard: int | None = None):
        if shard is None:
            self.valid = jnp.ones_like(self.valid)
        else:
            self.valid = self.valid.at[shard].set(True)

    # ---------------------------------------------------------- serving ----
    def prefill(self, batch: dict) -> Any:
        state = self.model.init_decode(self.params, batch,
                                       batch["tokens"].shape[0],
                                       self.scfg.max_len,
                                       self.scfg.cache_dtype,
                                       valid=self.valid)
        # run the prompt through decode in one chunk (teacher-forced fill)
        logits, state = self.model.decode(self.params, state,
                                          batch["tokens"], self.valid)
        return logits, state

    def generate(self, batch: dict, n_tokens: int,
                 fail_at: dict[int, int] | None = None) -> np.ndarray:
        """Greedy generation; ``fail_at`` maps step -> shard to kill mid-
        request (the paper's Case Study II: performance unchanged)."""
        logits, state = self.prefill(batch)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out = [tok]
        for t in range(n_tokens - 1):
            if fail_at and t in fail_at:
                self.inject_failure(fail_at[t])
            logits, state = self._decode(self.params, state, tok, self.valid)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(tok)
        self.metrics["requests"] += batch["tokens"].shape[0]
        return np.concatenate([np.asarray(t) for t in out], axis=1)

    # ------------------------------------------------- straggler model ----
    def straggler_latency(self, straggler: StragglerModel,
                          n_trials: int = 10000, seed: int = 0) -> dict:
        """First-T-of-(T+r) request-latency distribution across the coded
        shard set (paper Fig. 14/15): the engine's pod-level dispatch only
        needs T of T+r shard responses."""
        T = int(self.model.ctx.tp)
        r = int(self.model.ctx.code_r if self.model.ctx.coded else 0)
        rng = np.random.default_rng(seed)
        times = straggler.sample(rng, (n_trials, T + r))
        coded = request_latency(times, T)
        uncoded = request_latency(times[:, :T], T)
        return {
            "mean_coded_ms": float(coded.mean()),
            "mean_uncoded_ms": float(uncoded.mean()),
            "p99_coded_ms": float(np.percentile(coded, 99)),
            "p99_uncoded_ms": float(np.percentile(uncoded, 99)),
        }
