"""Serving stepper: the model-facing half of the coded serving stack.

This module used to be a monolithic synchronous engine; the scheduling,
failure-policy, and telemetry concerns now live in ``repro.runtime``. What
remains here is the *stepper* — the minimal stateful object the runtime
drives:

  * ``ModelStepper``: owns the CDC-encoded params and the jitted decode
    step; exposes prefill / decode-one-token / re-encode. It never looks
    at clocks, queues, or failure policy — the runtime feeds it the
    CURRENT validity mask each call, so a shard loss mid-request is
    recovered inside the same XLA program (close-to-zero recovery: no
    re-dispatch, no weight reload, no recompute — paper §5.2).
  * ``ServingEngine``: the legacy one-batch-at-a-time facade, kept for
    direct scripted use and the original integration tests; it is now a
    thin wrapper over ``ModelStepper``.

Straggler mitigation (§6.2) stays here as a latency *model*: a synchronous
TPU mesh can't skip laggards inside a step, so the stepper exposes the
paper's first-T-of-(T+r) order-statistic distribution for the pod/DCN
boundary, simulated with the measured per-shard latencies (core.failure).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.failure import StragglerModel, request_latency
from repro.models.zoo import Model
# tracer module only (no package init): keeps serve <-> runtime acyclic
from repro.obs.tracer import NULL_RECORDER


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 2048
    batch: int = 8
    cache_dtype: Any = jnp.float32
    greedy: bool = True


class ModelStepper:
    """Thin model stepper the runtime drives.

    Holds encoded params + one jitted decode function; all slot states are
    caller-owned pytrees, so the runtime can keep any number of independent
    decode slots (continuous batching) over a single compiled step.
    """

    def __init__(self, model: Model, params, max_len: int,
                 cache_dtype: Any = jnp.float32, tracer=None):
        self.model = model
        self.max_len = int(max_len)
        self.cache_dtype = cache_dtype
        # flight recorder (repro.obs); the scheduler re-binds its own so
        # code-geometry changes land in the same event stream
        self.tracer = tracer if tracer is not None else NULL_RECORDER
        self._raw_params = params
        self.params = model.encode_offline(params)
        self.coded = bool(model.ctx.coded)
        self.n_shards = max(int(model.ctx.tp), 1)
        spec = model.ctx.spec
        self.erasure_budget = int(spec.max_device_failures) if spec else 0
        # reads self.model at trace time so set_code_r's swapped context is
        # picked up: an r change alters the parity-leaf shapes, which is
        # exactly what keys a fresh jit trace
        self._decode = jax.jit(
            lambda p, st, tok, valid: self.model.decode(p, st, tok, valid))
        # span emission points (obs.spans): MEASURED dispatch-side wall
        # cost of the last prefill / parity re-encode. Wall-clock only —
        # quarantined in span wall_args, never in the simulated timeline.
        self.last_prefill_wall_ms: float = 0.0
        self.last_reencode_wall_ms: float = 0.0

    # ------------------------------------------------------------ coding ----
    def reencode(self):
        """Offline parity re-encode (paper §5.1): run after a healed shard
        rejoins or a standby replica is swapped in."""
        t0 = time.perf_counter()
        self.params = self.model.encode_offline(self._raw_params)
        self.last_reencode_wall_ms = (time.perf_counter() - t0) * 1e3

    def set_code_r(self, code_r: int) -> bool:
        """Re-size the parity budget (adaptive redundancy): rebuild the
        coded context and re-encode parity offline — the same heal +
        re-encode path a replica swap takes, plus a round retrace since
        the parity-weight shapes change. Decode slot states (KV caches)
        are r-independent, so in-flight requests carry straight on.
        Returns True iff the geometry changed."""
        code_r = int(code_r)
        if code_r < 0:
            raise ValueError(f"code_r must be >= 0, got {code_r}")
        if not self.coded or code_r == int(self.model.ctx.code_r):
            return False
        r_old = int(self.model.ctx.code_r)
        ctx = dataclasses.replace(self.model.ctx, code_r=code_r)
        self.model = dataclasses.replace(self.model, ctx=ctx)
        self.params = self.model.encode_offline(self._raw_params)
        spec = ctx.spec
        self.erasure_budget = int(spec.max_device_failures) if spec else 0
        if self.tracer.enabled:
            self.tracer.emit("code.resize", track="rounds", r_old=r_old,
                             r_new=code_r, budget=self.erasure_budget)
        return True

    def full_mask(self) -> np.ndarray:
        return np.ones(self.n_shards, bool)

    def _mask(self, valid) -> jax.Array | None:
        if valid is None:
            return None
        return jnp.asarray(np.asarray(valid, bool))

    # ---------------------------------------------------------- stepping ----
    def prefill(self, batch: dict, valid=None,
                per_row: bool = False) -> tuple[jax.Array, Any]:
        """Run the prompt through the decode path, filling a fresh slot
        state. Returns (last-position logits [b, 1, V], state).

        per_row=True builds the slot-batched cache layout (per-row position
        vectors) so the state can be written into a stacked executor batch.
        """
        t0 = time.perf_counter()
        v = self._mask(valid) if self.coded else None
        b = batch["tokens"].shape[0]
        state = self.model.init_decode(self.params, batch, b, self.max_len,
                                       self.cache_dtype, valid=v,
                                       per_row=per_row)
        logits, state = self._decode(self.params, state, batch["tokens"], v)
        self.last_prefill_wall_ms = (time.perf_counter() - t0) * 1e3
        return logits[:, -1:], state

    def decode_one(self, state, tok: jax.Array, valid=None
                   ) -> tuple[jax.Array, Any]:
        """One decode step: tok [b, 1] -> (logits [b, 1, V], new state)."""
        v = self._mask(valid) if self.coded else None
        return self._decode(self.params, state, tok, v)

    @staticmethod
    def greedy(logits: jax.Array) -> jax.Array:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    # ------------------------------------------------- straggler model ----
    def straggler_latency(self, straggler: StragglerModel,
                          n_trials: int = 10000, seed: int = 0) -> dict:
        """First-T-of-(T+r) request-latency distribution across the coded
        shard set (paper Fig. 14/15): pod-level dispatch only needs T of
        T+r shard responses."""
        T = self.n_shards
        r = int(self.model.ctx.code_r if self.coded else 0)
        rng = np.random.default_rng(seed)
        times = straggler.sample(rng, (n_trials, T + r))
        coded = request_latency(times, T)
        uncoded = request_latency(times[:, :T], T)
        return {
            "mean_coded_ms": float(coded.mean()),
            "mean_uncoded_ms": float(uncoded.mean()),
            "p99_coded_ms": float(np.percentile(coded, 99)),
            "p99_uncoded_ms": float(np.percentile(uncoded, 99)),
        }


class ServingEngine:
    """Legacy synchronous facade over ``ModelStepper``.

    One batch at a time, caller-managed failure injection. New code should
    use ``repro.runtime.ContinuousBatchingScheduler``, which drives the
    same stepper under sustained load with a shard-health controller.

    ``generate`` DELEGATES to the batched ``SlotPoolExecutor`` (every
    batch row becomes a slot, rounds are one dispatch) so this deprecated
    entry point exercises the exact same hot path as the runtime and
    cannot silently diverge from it — for every zoo family, enc-dec and
    xLSTM included. ``_generate_sequential`` remains as the
    differential-test oracle.
    """

    def __init__(self, model: Model, params, scfg: ServeConfig):
        self.model = model
        self.scfg = scfg
        self.stepper = ModelStepper(model, params, scfg.max_len,
                                    scfg.cache_dtype)
        self.valid = jnp.ones(self.stepper.n_shards, bool)
        self.metrics = {"requests": 0, "erasures_recovered": 0,
                        "requeued": 0}
        self._executors: dict[int, Any] = {}   # batch size -> warm executor

    @property
    def params(self):
        return self.stepper.params

    # -------------------------------------------------------- failures ----
    def inject_failure(self, shard: int):
        """Mark a TP shard dead. Subsequent steps recover via parity."""
        self.valid = self.valid.at[shard].set(False)
        self.metrics["erasures_recovered"] += 1

    def heal(self, shard: int | None = None):
        if shard is None:
            self.valid = jnp.ones_like(self.valid)
        else:
            self.valid = self.valid.at[shard].set(True)
        self.stepper.reencode()

    # ---------------------------------------------------------- serving ----
    def prefill(self, batch: dict) -> Any:
        logits, state = self.stepper.prefill(batch, self.valid)
        return logits, state

    def generate(self, batch: dict, n_tokens: int,
                 fail_at: dict[int, int] | None = None) -> np.ndarray:
        """Greedy generation; ``fail_at`` maps step -> shard to kill mid-
        request (the paper's Case Study II: performance unchanged)."""
        # deferred import: repro.runtime imports this module for the stepper
        from repro.runtime.executor import SlotPoolExecutor
        tokens = np.asarray(batch["tokens"])
        extras_all = {k: np.asarray(v) for k, v in batch.items()
                      if k != "tokens"}
        b = tokens.shape[0]
        ex = self._executors.get(b)
        if ex is None:
            ex = SlotPoolExecutor(self.stepper, n_slots=b, overlap=False)
            self._executors[b] = ex
        else:
            # reuse the warm jit cache; admission overwrites every row
            ex.drop_pending()
            ex.evict_all()
        out = np.zeros((b, n_tokens), np.int64)
        for i in range(b):
            extras = {k: v[i] for k, v in extras_all.items()} or None
            out[i, 0] = ex.admit(i, tokens[i], self.valid, tag=i,
                                 extras=extras)
        for t in range(n_tokens - 1):
            if fail_at and t in fail_at:
                self.inject_failure(fail_at[t])
            for slot, _, tok in ex.step_round(self.valid):
                out[slot, t + 1] = tok
        self.metrics["requests"] += b
        return out

    def _generate_sequential(self, batch: dict, n_tokens: int,
                             fail_at: dict[int, int] | None) -> np.ndarray:
        """Sequential per-slot stepping — the differential-test oracle the
        batched path is pinned against (no longer a production path)."""
        logits, state = self.prefill(batch)
        tok = self.stepper.greedy(logits)
        out = [tok]
        for t in range(n_tokens - 1):
            if fail_at and t in fail_at:
                self.inject_failure(fail_at[t])
            logits, state = self.stepper.decode_one(state, tok, self.valid)
            tok = self.stepper.greedy(logits)
            out.append(tok)
        self.metrics["requests"] += batch["tokens"].shape[0]
        return np.concatenate([np.asarray(t) for t in out], axis=1)

    # ------------------------------------------------- straggler model ----
    def straggler_latency(self, straggler: StragglerModel,
                          n_trials: int = 10000, seed: int = 0) -> dict:
        return self.stepper.straggler_latency(straggler, n_trials, seed)
