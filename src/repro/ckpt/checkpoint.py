"""Sharded, atomic, async checkpointing with elastic re-mesh on restore.

Design (mirrors the paper's operational story at datacenter scale): the
paper pre-loads ALL weights + task files on every RPi so any device can take
over any role after a failure (§6 "Task Creation & Assignment"). Here the
checkpoint stores GLOBAL arrays + a manifest, so a restore may land on a
DIFFERENT mesh (fewer/more hosts — the 'pre-defined distribution file with
fewer devices') and the restore path re-shards via NamedSharding placement.

Properties:
  * atomic: writes into step_XXXX.tmp/, fsyncs, then os.replace -> step_XXXX
  * async: save() returns immediately; a worker thread drains a queue
  * self-describing: manifest.json records shapes/dtypes/tree structure
  * elastic: restore(mesh=...) places leaves under any mesh's shardings
  * CDC-aware: parity leaves ("cdc") can be dropped on save and re-encoded
    offline on load (encode_tree), exactly like the paper's offline prep
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_SENTINEL = object()


def _flatten(tree: Any):
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)

    def name(p):
        parts = []
        for k in p:
            if isinstance(k, jax.tree_util.DictKey):
                parts.append(str(k.key))
            elif isinstance(k, jax.tree_util.SequenceKey):
                parts.append(f"#{k.idx}")
            else:
                parts.append(str(k))
        return "/".join(parts)

    return [(name(p), leaf) for p, leaf in paths_leaves], treedef


def save(tree: Any, directory: str, step: int, *,
         drop_parity: bool = True) -> str:
    """Synchronous atomic save of a pytree of (possibly sharded) arrays."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    named, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for name, leaf in named:
        if drop_parity and name.endswith("/cdc"):
            manifest["leaves"].append(
                {"name": name, "kind": "parity"})  # re-encoded on load
            continue
        arr = np.asarray(jax.device_get(leaf))
        store = arr
        if str(arr.dtype) == "bfloat16":  # numpy can't round-trip bf16
            store = arr.view(np.uint16)
        fn = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), store)
        manifest["leaves"].append(
            {"name": name, "kind": "array", "file": fn,
             "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(template: Any, directory: str, step: int | None = None, *,
            mesh=None, shardings: Any = None, encode_ctx=None) -> Any:
    """Restore into the structure of ``template`` (values replaced).

    mesh/shardings: if given, leaves are device_put with those shardings —
    this is the ELASTIC path: the same checkpoint restores onto any mesh
    (the paper's degraded redistribution, without losing a request).
    encode_ctx: TPCtx — recompute parity leaves offline after load.
    """
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {e["name"]: e for e in manifest["leaves"]}

    named, treedef = _flatten(template)
    shard_named = _flatten(shardings)[0] if shardings is not None else None
    out = []
    for i, (name, tmpl) in enumerate(named):
        entry = by_name.get(name)
        if entry is None or entry["kind"] == "parity":
            out.append(tmpl)  # parity re-encoded below / missing kept
            continue
        arr = np.load(os.path.join(path, entry["file"]))
        if entry["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        elif str(arr.dtype) != entry["dtype"]:
            arr = arr.astype(np.dtype(entry["dtype"]))
        if shard_named is not None:
            leaf = jax.device_put(arr, shard_named[i][1])
        elif mesh is not None:
            leaf = jax.device_put(arr)
        else:
            leaf = jnp.asarray(arr)
        out.append(leaf)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if encode_ctx is not None and encode_ctx.coded:
        from repro.models.common import encode_tree
        tree = encode_tree(tree, encode_ctx)
    return tree


class AsyncCheckpointer:
    """Fire-and-forget background saves (training never stalls on I/O)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: list[BaseException] = []
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is _SENTINEL:
                return
            tree, step = item
            try:
                save(tree, self.directory, step)
                self._gc()
            except BaseException as e:  # surfaced on next save()/close()
                self._err.append(e)

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def save(self, tree: Any, step: int):
        if self._err:
            raise self._err.pop()
        # device_get NOW so the trainer can donate/overwrite buffers
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((host_tree, step))

    def close(self):
        self._q.put(_SENTINEL)
        self._t.join(timeout=300)
        if self._err:
            raise self._err.pop()
