from repro.ckpt.checkpoint import (AsyncCheckpointer, latest_step, restore,
                                   save)
