"""Deterministic fault injection over the coded shard/device set.

The paper's premise is IoT hardware with "unstable latencies and
intermittent failures"; until now the runtime only reacted to hand-placed
``ShardEvent``s. This module generates realistic failure schedules and
drives the existing ``ShardHealthController`` with them, advancing on the
runtime's simulated clock so a whole chaos run replays bit-exact from one
root seed (``faults.seeds``).

Two interchangeable sources (same ``events_until`` / ``slowdown_at``
surface):

  * ``FaultInjector`` — a seeded per-device up/down churn process:
    time-to-failure is exponential or Weibull (wear-out / infant
    mortality), repairs are exponential, a failure can be *transient*
    (erasure + later recovery), *permanent* (erasure, device never
    returns — only a 2MR replica swap heals it), or *degraded* (the
    device stays up but slow — no mask flip, picked up by the injected
    latency process). Correlated wireless dropouts model the paper's
    RPi-over-WiFi rig: devices are partitioned into AP groups and a
    burst takes a whole group down at once.
  * ``TraceInjector`` — plays back a recorded schedule (JSONL), e.g. the
    bundled 12-Pi-rig-flavoured trace of ``make_pi_rig_trace``, or any
    hand-written scenario.

The scheduler's per-round injection hook pumps ``events_until(now)`` into
``ShardHealthController.schedule``; the injector never touches masks
directly, so the CDC+2MR hybrid policy (budget gate, requeue, heal,
re-encode) stays the single decision point.
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import math
import os

import numpy as np

from repro.core.seeds import stream_rng
from repro.runtime.health import (EventKind, ShardEvent, erasure, recovery,
                                  replica_failure)

UP, DOWN, DEAD, DEGRADED = 0, 1, 2, 3


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """Parameters of the churn process (all times in ms, per device)."""

    mtbf_ms: float = 400.0        # mean time between failures
    mttr_ms: float = 50.0         # mean transient repair time
    fail_dist: str = "exponential"   # "exponential" | "weibull"
    weibull_k: float = 1.5        # Weibull shape (>1: wear-out tail)
    p_permanent: float = 0.0      # failure is permanent (no recovery event)
    p_degraded: float = 0.0       # failure is a slowdown, not an erasure
    degraded_factor: float = 4.0  # latency multiplier while degraded
    groups: int = 0               # wireless AP groups (0: no bursts)
    burst_mtbf_ms: float = 0.0    # mean time between correlated dropouts
    burst_down_ms: float = 30.0   # dropout duration (whole group down)

    def __post_init__(self):
        if self.mtbf_ms <= 0 or self.mttr_ms <= 0:
            raise ValueError("mtbf_ms/mttr_ms must be > 0")
        if self.fail_dist not in ("exponential", "weibull"):
            raise ValueError(f"unknown fail_dist {self.fail_dist!r}")
        if self.weibull_k <= 0:
            raise ValueError("weibull_k must be > 0")
        if not (0 <= self.p_permanent + self.p_degraded <= 1):
            raise ValueError("p_permanent + p_degraded must lie in [0, 1]")
        if self.groups and self.burst_mtbf_ms <= 0:
            raise ValueError("groups > 0 needs burst_mtbf_ms > 0")


class FaultInjector:
    """Seeded churn over ``n_shards`` devices; see module docstring."""

    def __init__(self, spec: ChaosSpec, n_shards: int, seed: int = 0):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.spec = spec
        self.n_shards = int(n_shards)
        self.seed = int(seed)
        self.rng = stream_rng(seed, "injector")
        self.state = np.full(self.n_shards, UP, np.int8)
        self._burst_down: set[int] = set()
        # degraded intervals (t0, t1, shard, factor) for slowdown_at()
        self.degraded: list[tuple[float, float, int, float]] = []
        self._now = 0.0
        self._seq = 0
        self._heap: list[tuple[float, int, str, int]] = []
        for d in range(self.n_shards):
            self._push(self._draw_ttf(), "fail", d)
        if spec.groups:
            self._push(self.rng.exponential(spec.burst_mtbf_ms), "burst", -1)

    # ---------------------------------------------------------- process ----
    def _push(self, t: float, kind: str, who: int):
        heapq.heappush(self._heap, (float(t), self._seq, kind, who))
        self._seq += 1

    def _draw_ttf(self) -> float:
        s = self.spec
        if s.fail_dist == "weibull":
            # scale so the mean stays mtbf_ms regardless of shape k
            scale = s.mtbf_ms / math.gamma(1.0 + 1.0 / s.weibull_k)
            return scale * float(self.rng.weibull(s.weibull_k))
        return float(self.rng.exponential(s.mtbf_ms))

    def _draw_repair(self) -> float:
        return float(self.rng.exponential(self.spec.mttr_ms))

    def _group(self, g: int) -> list[int]:
        return [d for d in range(self.n_shards)
                if d % self.spec.groups == g]

    def events_until(self, now_ms: float) -> list[ShardEvent]:
        """Advance the churn process to ``now_ms`` (monotone) and return
        every mask-flip event that fired, in time order."""
        if now_ms < self._now:
            raise ValueError(f"injector time went backwards: "
                             f"{now_ms} < {self._now}")
        self._now = float(now_ms)
        out: list[ShardEvent] = []
        s = self.spec
        while self._heap and self._heap[0][0] <= now_ms:
            t, _, kind, who = heapq.heappop(self._heap)
            if kind == "fail":
                if self.state[who] != UP:       # already down/degraded
                    self._push(t + self._draw_ttf(), "fail", who)
                    continue
                u = float(self.rng.random())
                dur = self._draw_repair()
                if u < s.p_degraded:
                    self.state[who] = DEGRADED
                    self.degraded.append((t, t + dur, who,
                                          s.degraded_factor))
                    self._push(t + dur, "undegrade", who)
                elif u < s.p_degraded + s.p_permanent:
                    self.state[who] = DEAD      # only a replica swap heals
                    out.append(erasure(t, who))
                else:
                    self.state[who] = DOWN
                    out.append(erasure(t, who))
                    self._push(t + dur, "repair", who)
            elif kind == "repair":
                if self.state[who] == DOWN:
                    self.state[who] = UP
                    out.append(recovery(t, who))
                    self._push(t + self._draw_ttf(), "fail", who)
            elif kind == "undegrade":
                if self.state[who] == DEGRADED:
                    self.state[who] = UP
                    self._push(t + self._draw_ttf(), "fail", who)
            elif kind == "burst":
                g = int(self.rng.integers(s.groups))
                for d in self._group(g):
                    if self.state[d] == UP:
                        self.state[d] = DOWN
                        self._burst_down.add(d)
                        out.append(erasure(t, d))
                self._push(t + s.burst_down_ms, "burst_end", g)
                self._push(t + self.rng.exponential(s.burst_mtbf_ms),
                           "burst", -1)
            elif kind == "burst_end":
                for d in self._group(who):
                    if d in self._burst_down:
                        self._burst_down.discard(d)
                        self.state[d] = UP
                        out.append(recovery(t, d))
                        # the device's own pending "fail" stream survived
                        # the burst (it reschedules itself while non-UP),
                        # so restoring UP must NOT push another one — that
                        # would multiply failure streams per burst
        return out

    def sync_replaced(self, healthy_mask, now_ms: float):
        """Reconcile with the runtime's 2MR heal: a permanently-DEAD
        device that the health controller now reports healthy was
        physically replaced by a standby — resume its churn (fresh
        failure stream) so long runs don't progressively retire devices
        from the fault process."""
        for d in np.flatnonzero(np.asarray(healthy_mask, bool)):
            if self.state[d] == DEAD:
                self.state[d] = UP
                self._push(now_ms + self._draw_ttf(), "fail", int(d))

    def slowdown_at(self, t_ms: float) -> np.ndarray:
        """Per-device latency multiplier at ``t_ms`` (1.0 = healthy).
        Only valid up to the time the process has been advanced to.
        Expired intervals are pruned (``t_ms`` rises monotonically in
        runtime use), keeping the per-round scan bounded by the number
        of CONCURRENTLY degraded devices, not run length."""
        self.degraded = [iv for iv in self.degraded if iv[1] > t_ms]
        f = np.ones(self.n_shards, np.float64)
        for t0, t1, d, factor in self.degraded:
            if t0 <= t_ms < t1:
                f[d] = max(f[d], factor)
        return f

    # ------------------------------------------------------------ trace ----
    def to_trace(self, horizon_ms: float) -> list[dict]:
        """Run the process to ``horizon_ms`` and serialise the schedule
        (mask events + degraded intervals) as trace records. Use a FRESH
        injector: events are consumed exactly once and ``slowdown_at``
        prunes finished degraded intervals."""
        records = [_event_record(ev) for ev in self.events_until(horizon_ms)]
        records += [{"t_ms": t0, "kind": "degraded", "shard": d,
                     "until_ms": t1, "factor": f}
                    for t0, t1, d, f in self.degraded if t0 < horizon_ms]
        records.sort(key=lambda r: r["t_ms"])
        return records


# ------------------------------------------------------- trace playback ----

def _event_record(ev: ShardEvent) -> dict:
    return {"t_ms": ev.time_ms, "kind": ev.kind.value, "shard": ev.shard}


class TraceInjector:
    """Plays a recorded fault schedule back (same surface as the churn
    injector). Records: {"t_ms", "kind": erasure|recovery|replica_failure|
    degraded, "shard", ["until_ms", "factor"]}."""

    def __init__(self, records: list[dict], n_shards: int):
        self.n_shards = int(n_shards)
        self._events: list[ShardEvent] = []
        self.degraded: list[tuple[float, float, int, float]] = []
        for r in sorted(records, key=lambda r: float(r["t_ms"])):
            t, kind = float(r["t_ms"]), str(r["kind"])
            shard = int(r.get("shard", -1))
            if kind == "replica_failure":
                self._events.append(replica_failure(t))
                continue
            if not (0 <= shard < self.n_shards):
                raise ValueError(
                    f"trace names shard {shard} but the runtime has "
                    f"{self.n_shards} — record the trace for this rig or "
                    "shrink it")
            if kind == "degraded":
                self.degraded.append((t, float(r["until_ms"]), shard,
                                      float(r.get("factor", 4.0))))
                continue
            self._events.append(ShardEvent(t, EventKind(kind), shard))
        self._cursor = 0
        self._now = 0.0

    @classmethod
    def from_file(cls, path: str, n_shards: int) -> "TraceInjector":
        return cls(load_trace(path), n_shards)

    def events_until(self, now_ms: float) -> list[ShardEvent]:
        if now_ms < self._now:
            raise ValueError(f"injector time went backwards: "
                             f"{now_ms} < {self._now}")
        self._now = float(now_ms)
        out = []
        while (self._cursor < len(self._events)
               and self._events[self._cursor].time_ms <= now_ms):
            out.append(self._events[self._cursor])
            self._cursor += 1
        return out

    def slowdown_at(self, t_ms: float) -> np.ndarray:
        f = np.ones(self.n_shards, np.float64)
        for t0, t1, d, factor in self.degraded:
            if t0 <= t_ms < t1:
                f[d] = max(f[d], factor)
        return f


def write_trace(path: str, records: list[dict]):
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r, sort_keys=True) + "\n")


def load_trace(path: str) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# ------------------------------------------------------ canned schedules ----

def make_pi_rig_trace(horizon_ms: float = 2000.0, n_shards: int = 12,
                      seed: int = 0) -> list[dict]:
    """A schedule flavoured like the paper's 12-RPi-over-WiFi rig: three
    4-Pi AP groups with correlated dropouts, heavy transient churn, a
    small permanent-failure and degraded-mode tail."""
    spec = ChaosSpec(mtbf_ms=600.0, mttr_ms=80.0, fail_dist="weibull",
                     weibull_k=1.3, p_permanent=0.05, p_degraded=0.15,
                     degraded_factor=5.0, groups=3, burst_mtbf_ms=900.0,
                     burst_down_ms=40.0)
    return FaultInjector(spec, n_shards, seed=seed).to_trace(horizon_ms)


def churn_trace(n_shards: int, t0_ms: float, t1_ms: float, period_ms: float,
                down_ms: float, concurrent: int = 1,
                first_shard: int = 0) -> list[dict]:
    """A deterministic in-budget churn phase: every ``period_ms`` inside
    [t0, t1), ``concurrent`` distinct shards go down together and recover
    ``down_ms`` later (must be < period so outages never overlap the next
    wave). Shards rotate so every device takes its turn failing."""
    if down_ms >= period_ms:
        raise ValueError("down_ms must be < period_ms (waves must not "
                         "overlap)")
    if concurrent > n_shards:
        raise ValueError("concurrent outages cannot exceed n_shards")
    records, shard, t = [], first_shard, t0_ms
    while t + down_ms < t1_ms:
        for j in range(concurrent):
            d = (shard + j) % n_shards
            records.append({"t_ms": t, "kind": "erasure", "shard": d})
            records.append({"t_ms": t + down_ms, "kind": "recovery",
                            "shard": d})
        shard = (shard + concurrent) % n_shards
        t += period_ms
    return records


# -------------------------------------------------------------- parsing ----

_SPEC_KEYS = {
    "mtbf": "mtbf_ms", "mtbf_ms": "mtbf_ms",
    "mttr": "mttr_ms", "mttr_ms": "mttr_ms",
    "k": "weibull_k", "weibull_k": "weibull_k",
    "p_perm": "p_permanent", "p_permanent": "p_permanent",
    "p_deg": "p_degraded", "p_degraded": "p_degraded",
    "deg_factor": "degraded_factor", "degraded_factor": "degraded_factor",
    "groups": "groups",
    "burst_mtbf": "burst_mtbf_ms", "burst_mtbf_ms": "burst_mtbf_ms",
    "burst_down": "burst_down_ms", "burst_down_ms": "burst_down_ms",
}


def parse_chaos(arg: str, n_shards: int, seed: int = 0):
    """``--chaos`` argument -> injector. A path to a JSONL trace plays it
    back; otherwise a spec string like
    ``"weibull:mtbf=300,mttr=40,p_perm=0.05,groups=2,burst_mtbf=500"``
    (dist prefix optional, keys per ``ChaosSpec``)."""
    if os.path.exists(arg):
        return TraceInjector.from_file(arg, n_shards)
    dist, _, body = arg.partition(":")
    if not body:
        dist, body = "exponential", arg
    dist = {"exp": "exponential", "exponential": "exponential",
            "weibull": "weibull"}.get(dist)
    if dist is None:
        raise ValueError(f"unknown chaos distribution in {arg!r}")
    kw: dict = {"fail_dist": dist}
    for pair in filter(None, body.split(",")):
        key, _, val = pair.partition("=")
        field = _SPEC_KEYS.get(key.strip())
        if field is None:
            raise ValueError(f"unknown chaos spec key {key!r} "
                             f"(known: {sorted(set(_SPEC_KEYS))})")
        kw[field] = int(val) if field == "groups" else float(val)
    return FaultInjector(ChaosSpec(**kw), n_shards, seed=seed)
