"""Root-seed stream derivation for the chaos harness.

The implementation lives in ``repro.core.seeds`` (dependency-free, so
the runtime scheduler can share it without a runtime <-> faults package
cycle); this module re-exports it as part of the faults API.
"""
from repro.core.seeds import stream_rng, stream_seed

__all__ = ["stream_rng", "stream_seed"]
