"""Fault-injection chaos harness + adaptive redundancy planner.

Turns the runtime from "recovers when told a shard died" into "survives
and re-plans under realistic failure scenarios":

  * ``injector`` — seeded Weibull/exponential up-down churn, transient vs
    permanent failures, correlated wireless dropouts, and trace playback
    (the paper's 12-Pi rig flavour), feeding the existing
    ``ShardHealthController`` through the scheduler's per-round hook;
  * ``latency`` — an injected per-device latency process layered onto
    ``core.failure.StragglerModel`` so modelled and measured round
    latency describe the same fault schedule;
  * ``planner`` — estimates per-window failure rates from what the
    runtime observed and re-sizes r (and the CDC-vs-2MR hybrid split) to
    meet a target availability, applied through heal + parity re-encode;
  * ``seeds`` — one root seed fanned into independent streams so a whole
    chaos run replays bit-exact.
"""
from repro.faults.injector import (ChaosSpec, FaultInjector, TraceInjector,
                                   churn_trace, load_trace,
                                   make_pi_rig_trace, parse_chaos,
                                   write_trace)
from repro.faults.latency import (InjectedLatency, LatencySpec,
                                  measured_stall_hook)
from repro.faults.planner import (AdaptiveRedundancyPlanner, PlannerConfig,
                                  RedundancyPlan, apply_plan,
                                  attach_planner, binomial_tail,
                                  required_budget)
from repro.faults.seeds import stream_rng, stream_seed


def attach_chaos(sched, injector):
    """Register the injector as a per-round scheduler hook: every round,
    pump the fault events due by now into the health controller (which
    applies the CDC+2MR hybrid policy exactly as for hand-placed
    events), and reconcile permanently-dead devices the controller has
    since healed via a 2MR replica swap (the standby hardware resumes
    churning)."""
    sched.injector = injector
    sync = getattr(injector, "sync_replaced", None)

    def hook(s):
        now = s.clock.now()
        if sync is not None:
            sync(s.health.mask, now)
        for ev in injector.events_until(now):
            s.health.schedule(ev)
            s.metrics.count("faults_injected")
            if s.tracer.enabled:
                # one fault.inject per injected event; the scheduler's
                # health handling emits its resolution (fault.recovered /
                # fault.beyond_budget / fault.noop) when the event applies
                s.tracer.emit(
                    "fault.inject",
                    track=f"shard:{ev.shard}" if ev.shard >= 0 else "rounds",
                    t_ms=ev.time_ms, fault=ev.kind.value, shard=ev.shard)
    sched.round_hooks.append(hook)
    return hook


__all__ = [
    "ChaosSpec", "FaultInjector", "TraceInjector", "churn_trace",
    "load_trace", "make_pi_rig_trace", "parse_chaos", "write_trace",
    "InjectedLatency", "LatencySpec", "measured_stall_hook",
    "AdaptiveRedundancyPlanner", "PlannerConfig", "RedundancyPlan",
    "apply_plan", "attach_planner", "binomial_tail", "required_budget",
    "stream_rng", "stream_seed",
    "attach_chaos",
]
