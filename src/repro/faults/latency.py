"""Injected per-device latency layered onto ``core.failure.StragglerModel``.

The scheduler's simulated clock normally advances by a *healthy-cluster*
first-T-of-(T+r) draw. Under chaos that understates reality: dead devices
contribute nothing, degraded devices respond slower, and an uncoded round
must wait for (or time out on) every straggler. ``InjectedLatency`` makes
the modelled round latency consult the SAME fault schedule the injector
feeds the health controller, so the modelled series
(``snapshot()["elapsed_ms"]`` etc.) and the measured wall-clock series
(``RuntimeMetrics.round_ms``) describe one consistent scenario and can be
compared side by side.

Model per round at time t (paper §6.2 order statistics, extended):

  * every responder draws ``base`` (floor + lognormal), multiplied by the
    injector's ``slowdown_at(t)`` for degraded devices;
  * dead devices (the health mask) never respond;
  * a coded round completes at the T-th arrival of the T + r responders
    that are still alive — in-budget erasures cost only the lost order
    statistic, the paper's close-to-zero recovery;
  * an uncoded round needs ALL T data devices; a dead one stalls the
    round until ``timeout_ms`` — the degraded-redistribution cliff CDC
    avoids.

``measured_stall_hook`` mirrors the same schedule into the MEASURED path:
an executor round hook that stalls the host dispatch by the modelled
stall times ``wall_scale`` (default 1/1000: 1 modelled ms = 1 wall µs),
so chaos benchmarks show the injected phases in ``round_ms`` without
slowing wall-clock runs materially.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.failure import StragglerModel
from repro.core.seeds import stream_rng


@dataclasses.dataclass(frozen=True)
class LatencySpec:
    base: StragglerModel = dataclasses.field(default_factory=StragglerModel)
    timeout_ms: float = 1000.0     # uncoded stall on a dead device
    # folded layout (the repo default): parity slice j rides data device
    # j % T, so that device's death/slowdown takes its parity along.
    # Set False for the dedicated layout's independent parity devices.
    parity_rides_data: bool = True

    def __post_init__(self):
        if self.timeout_ms <= 0:
            raise ValueError("timeout_ms must be > 0")


class InjectedLatency:
    """Stateful modelled-latency process over an injector's schedule.

    Draws are an independent seeded stream (``faults.seeds``), so the
    scheduler, injector, and latency model reproduce bit-exact from one
    root seed no matter how often each draws.
    """

    def __init__(self, spec: LatencySpec, injector, seed: int = 0):
        self.spec = spec
        self.injector = injector
        self.rng = stream_rng(seed, "latency")
        self.last_round_ms: float = 0.0
        # stall = last round's excess over its FAULT-FREE counterfactual
        # (same base draw, no slowdowns, full mask): the deterministic
        # per-round straggler/fault cost obs.spans charges to the `stall`
        # span of every decode slice that rode the round
        self.last_stall_ms: float = 0.0

    def _shard_times(self, now_ms: float, T: int, r: int,
                     mask: np.ndarray | None,
                     base: np.ndarray | None = None) -> np.ndarray:
        """[T + r] per-responder times; dead responders are +inf."""
        times = self.spec.base.sample(self.rng, (T + r,)) \
            if base is None else base.copy()
        slow = self.injector.slowdown_at(now_ms)
        times[:T] *= slow[:T]
        if r and self.spec.parity_rides_data:
            times[T:] *= np.resize(slow[:T], r)
        if mask is not None:
            dead = ~np.asarray(mask, bool)
            times[:T][dead] = np.inf
            if r and self.spec.parity_rides_data:
                times[T:][np.resize(dead, r)] = np.inf
        return times

    def round_ms(self, now_ms: float, T: int, r: int,
                 mask: np.ndarray | None = None) -> float:
        """Modelled latency of one coded (r > 0) or uncoded (r == 0)
        decode round at ``now_ms`` under the injected fault state."""
        # ONE base draw per round (RNG consumption identical to before the
        # stall accounting existed — replays stay bit-exact): the clean
        # counterfactual reuses it with no slowdowns and a full mask.
        base = self.spec.base.sample(self.rng, (T + r,))
        if r:
            clean = float(np.sort(base)[T - 1])
        else:
            clean = float(base[:T].max())
        clean = min(clean, self.spec.timeout_ms)
        times = self._shard_times(now_ms, T, r, mask, base=base)
        if r:
            dt = float(np.sort(times)[T - 1])   # T-th of the T+r arrivals
        else:
            dt = float(times[:T].max())         # wait for every data shard
        dt = min(dt, self.spec.timeout_ms)
        self.last_round_ms = dt
        self.last_stall_ms = max(0.0, dt - clean)
        return dt


def measured_stall_hook(latency: InjectedLatency, wall_scale: float = 1e-3):
    """Executor round hook replaying the modelled stall into wall time.

    Stalls the dispatch by ``last_round_ms * wall_scale``. The scheduler
    draws the modelled latency AFTER dispatching, so the stall replayed
    into round N is round N-1's draw (round 1 is unstalled): the
    MEASURED ``RuntimeMetrics.round_ms`` series shows the same fault
    phases as the modelled one at a compressed timescale, shifted by one
    round at phase edges — a diagnostic overlay, not a synchronised
    measurement."""

    def hook(executor, valid):
        dt = latency.last_round_ms * wall_scale
        if dt > 0:
            time.sleep(dt / 1e3)
    return hook
