"""Adaptive redundancy planner: close the loop from observed faults to r.

The runtime so far ran a *fixed* (T, r) parity budget. This planner
watches what actually happens — per-window device unavailability, the
worst number of concurrent dead shards, straggler pressure — and re-sizes
the redundancy to meet a target availability, applying the change through
the existing heal + parity re-encode path (``ModelStepper.set_code_r`` +
``ShardHealthController.set_budget``). The CDC-vs-2MR hybrid split is
part of the plan: CDC-suitable splits (Table 1, ``core.policy``) spend
the budget on parity shards (constant cost in device count); unsuitable
splits cannot carry offline parity, so the same tolerance target is met
with standby 2MR replicas instead (linear cost — the paper's headline
contrast).

Sizing: with per-device unavailability ``u`` (EWMA of the observed
dead-device-rounds fraction), concurrent dead shards are modelled as
Binomial(T, u); the budget ``b`` is the smallest count whose tail
``P(X > b) <= 1 - target``, floored by the worst concurrency actually
observed in the window (the estimator must never plan below reality).
Budget -> r via the code layout: folded parity tolerates ``r // 2``
device failures, dedicated tolerates ``r``. Raising r is immediate;
lowering waits ``cooldown_windows`` consecutive calm windows so a lull
between correlated bursts doesn't strip protection.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


def binomial_tail(n: int, p: float, b: int) -> float:
    """P(X > b) for X ~ Binomial(n, p)."""
    if p <= 0.0:
        return 0.0
    if p >= 1.0:
        return 1.0 if b < n else 0.0
    return float(sum(math.comb(n, k) * p ** k * (1.0 - p) ** (n - k)
                     for k in range(b + 1, n + 1)))


def required_budget(n_devices: int, unavail: float, target: float,
                    b_max: int) -> int:
    """Smallest b <= b_max with P(concurrent dead > b) <= 1 - target."""
    for b in range(b_max + 1):
        if binomial_tail(n_devices, unavail, b) <= 1.0 - target:
            return b
    return b_max


@dataclasses.dataclass(frozen=True)
class PlannerConfig:
    target_availability: float = 0.999
    window_ms: float = 100.0       # estimation window (sim time)
    min_budget: int = 1            # never plan below this tolerance
    max_budget: int = 2            # cap (r <= 2*b folded / b dedicated)
    ewma: float = 0.5              # weight of the newest window estimate
    cooldown_windows: int = 2      # calm windows required before lowering

    def __post_init__(self):
        if not (0.0 < self.target_availability < 1.0):
            raise ValueError("target_availability must lie in (0, 1)")
        if self.window_ms <= 0:
            raise ValueError("window_ms must be > 0")
        if not (0 <= self.min_budget <= self.max_budget):
            raise ValueError("need 0 <= min_budget <= max_budget")
        if not (0.0 < self.ewma <= 1.0):
            raise ValueError("ewma must lie in (0, 1]")


@dataclasses.dataclass(frozen=True)
class RedundancyPlan:
    t_ms: float
    budget: int                    # concurrent device failures to tolerate
    r: int                         # parity shards implementing the budget
    standby_replicas: int          # 2MR half of the hybrid
    est_unavailability: float
    window_max_dead: int
    reason: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class AdaptiveRedundancyPlanner:
    """Windowed estimator + budget sizing; drive with ``observe_round``
    every decode round and act on what ``maybe_plan`` returns."""

    def __init__(self, cfg: PlannerConfig, n_shards: int,
                 layout: str = "folded", suitable: bool = True,
                 init_budget: int | None = None):
        if layout not in ("folded", "dedicated"):
            raise ValueError(layout)
        self.cfg = cfg
        self.n_shards = int(n_shards)
        self.layout = layout
        self.suitable = bool(suitable)
        self.budget = int(cfg.min_budget if init_budget is None
                          else init_budget)
        self.unavail = 0.0
        self.plans: list[RedundancyPlan] = []
        self._calm_windows = 0
        self._win_start: float | None = None
        self._win_rounds = 0
        self._win_dead_rounds = 0
        self._win_max_dead = 0

    # ------------------------------------------------------- observation ----
    def observe_round(self, now_ms: float, mask: np.ndarray):
        if self._win_start is None:
            self._win_start = float(now_ms)
        n_dead = int((~np.asarray(mask, bool)).sum())
        self._win_rounds += 1
        self._win_dead_rounds += n_dead
        self._win_max_dead = max(self._win_max_dead, n_dead)

    # ------------------------------------------------------------ sizing ----
    def r_for_budget(self, budget: int) -> int:
        """Parity shards implementing ``budget`` under the code layout
        (folded parity rides the data devices: a death costs the data
        shard AND its folded slices, hence the factor 2)."""
        if not self.suitable or budget == 0:
            return 0
        r = 2 * budget if self.layout == "folded" else budget
        return min(r, self.n_shards)     # CodeSpec caps r at T

    def maybe_plan(self, now_ms: float, health=None) -> RedundancyPlan | None:
        """Close the window if due; returns a plan exactly at window
        boundaries, None in between. ``health`` (the live
        ``ShardHealthController``) contributes its concurrent-dead
        high-water mark — a beyond-budget burst heals inside one round,
        so per-round mask samples alone would miss it."""
        if (self._win_start is None or self._win_rounds == 0
                or now_ms - self._win_start < self.cfg.window_ms):
            return None
        if health is not None:
            self._win_max_dead = max(self._win_max_dead,
                                     health.drain_peak_dead())
        u_win = self._win_dead_rounds / (self.n_shards * self._win_rounds)
        self.unavail = (self.cfg.ewma * u_win
                        + (1.0 - self.cfg.ewma) * self.unavail)
        need = required_budget(self.n_shards, self.unavail,
                               self.cfg.target_availability,
                               self.cfg.max_budget)
        # the estimator must never plan below observed reality
        need = max(need, min(self._win_max_dead, self.cfg.max_budget),
                   self.cfg.min_budget)
        if need > self.budget:
            self.budget, self._calm_windows = need, 0
            reason = f"raise: tail({self.unavail:.4f}) needs b={need}"
        elif need < self.budget:
            self._calm_windows += 1
            if self._calm_windows >= self.cfg.cooldown_windows:
                self.budget, self._calm_windows = need, 0
                reason = f"lower after {self.cfg.cooldown_windows} calm " \
                         f"windows: b={need}"
            else:
                reason = (f"hold b={self.budget} (calm "
                          f"{self._calm_windows}/"
                          f"{self.cfg.cooldown_windows})")
        else:
            self._calm_windows = 0
            reason = f"hold b={self.budget}"
        plan = RedundancyPlan(
            t_ms=float(now_ms), budget=self.budget,
            r=self.r_for_budget(self.budget),
            standby_replicas=(1 if self.suitable
                              else max(1, self.budget)),
            est_unavailability=float(self.unavail),
            window_max_dead=self._win_max_dead, reason=reason)
        self.plans.append(plan)
        self._win_start = float(now_ms)
        self._win_rounds = self._win_dead_rounds = self._win_max_dead = 0
        return plan


# ------------------------------------------------------------- wiring ----

def apply_plan(sched, plan: RedundancyPlan) -> bool:
    """Apply a plan to a live scheduler through the heal + re-encode path.

    Never shrinks the budget below the shards currently dead (a code that
    cannot cover the present mask would break in-flight decode). Returns
    True iff the code geometry actually changed (which re-encodes parity
    and retraces the round on its next dispatch).
    """
    stepper, health = sched.stepper, sched.health
    if not stepper.coded or plan.r == 0:
        return False
    r = plan.r
    if health.n_dead > plan.budget:
        layout = stepper.model.ctx.code_layout
        r = min(2 * health.n_dead if layout == "folded" else health.n_dead,
                stepper.n_shards)
    if not stepper.set_code_r(r):
        return False
    health.set_budget(stepper.erasure_budget)
    sched.metrics.count("replans")
    sched.metrics.count("parity_reencodes")
    shardlog = getattr(sched, "shardlog", None)
    if shardlog is not None:     # a resize re-encodes parity offline too
        shardlog.on_reencode(sched.clock.now())
    return True


def attach_planner(sched, planner: AdaptiveRedundancyPlanner):
    """Register the planner as a per-round scheduler hook: observe the
    current mask, re-plan at window boundaries, apply changes, and record
    the plan series into the run's metrics."""
    sched.planner = planner

    def hook(s):
        now = s.clock.now()
        planner.observe_round(now, s.health.mask)
        plan = planner.maybe_plan(now, health=s.health)
        if plan is not None:
            applied = apply_plan(s, plan)
            s.metrics.observe_plan(plan.as_dict(), applied)
            if s.tracer.enabled:
                d = plan.as_dict()
                s.tracer.emit("planner.plan", track="planner",
                              t_ms=d.pop("t_ms"), applied=applied, **d)
    sched.round_hooks.append(hook)
    return hook
