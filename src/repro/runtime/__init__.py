"""Coded cluster runtime: continuous batching + shard health + telemetry.

The runtime layer turns the paper's per-request fault-tolerance math
(``repro.core``) and the model stepper (``repro.serve``) into a serving
system under sustained load: a deadline-aware request queue feeding a
fixed pool of decode slots, a batched slot executor advancing the whole
pool in one jitted dispatch per round (``repro.runtime.executor``), a
health controller applying the CDC+2MR hybrid policy to live erasure
events, and JSON-snapshot telemetry (modelled AND measured round
latency) for the benchmarks.
"""
from repro.runtime.clock import Clock, SimClock, WallClock
from repro.runtime.executor import (SlotPoolExecutor, VStep,
                                    supports_slot_batching)
from repro.runtime.health import (EventKind, HealthAction, ShardEvent,
                                  ShardHealthController, erasure, recovery,
                                  replica_failure)
from repro.runtime.metrics import RuntimeMetrics
from repro.runtime.queue import AdmissionQueue
from repro.runtime.request import Request, RequestState
from repro.runtime.scheduler import (ContinuousBatchingScheduler,
                                     RuntimeConfig, run_arrivals)

__all__ = [
    "Clock", "SimClock", "WallClock",
    "EventKind", "HealthAction", "ShardEvent", "ShardHealthController",
    "erasure", "recovery", "replica_failure",
    "RuntimeMetrics", "AdmissionQueue",
    "Request", "RequestState",
    "SlotPoolExecutor", "VStep", "supports_slot_batching",
    "ContinuousBatchingScheduler", "RuntimeConfig", "run_arrivals",
]
