"""Stack / unstack decode-slot states into one slot-batched pytree.

The scheduler's decode slots used to be independent batch-1 states, stepped
one `jax.jit` dispatch each. Here they live as ONE stacked pytree whose
batch axis IS the slot axis: every non-xLSTM decode-state leaf is laid out
``[L(layers), B(slots), ...]`` (``init_decode_state`` vmaps the per-layer
init over layers, so layers lead and the batch rides second). With the
per-row cache layout (``attention.init_cache(per_row=True)``) each row
carries its own KV length/positions, so rows decode at independent
positions inside a single dispatch, and slot admission overwrites one row
in place — same shapes every time, never a recompile.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

# every stacked decode-state leaf is [L, B, ...]: slots live on axis 1
SLOT_AXIS = 1


def supports_slot_batching(model) -> bool:
    """Slot batching needs the per-row KV-cache layout: decoder-only,
    non-xLSTM families (enc-dec slots need per-request encoder state and
    xLSTM carries positionless recurrent block state — see ROADMAP)."""
    cfg = model.cfg
    return not cfg.is_encdec and cfg.ssm_kind != "xlstm"


def blank_state(stepper, n_slots: int) -> Any:
    """A fresh stacked per-row decode state with ``n_slots`` rows."""
    return stepper.model.init_decode(stepper.params, {}, n_slots,
                                     stepper.max_len, stepper.cache_dtype,
                                     per_row=True)


def stack_states(states: list[Any]) -> Any:
    """Concatenate batch-1 per-row states along the slot axis."""
    return jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=SLOT_AXIS), *states)


@jax.jit
def _write_row(stacked, row, idx):
    return jax.tree.map(
        lambda s, x: jax.lax.dynamic_update_slice_in_dim(
            s, x.astype(s.dtype), idx, axis=SLOT_AXIS), stacked, row)


def write_slot(stacked: Any, idx, row: Any) -> Any:
    """Write a (batch-1, per-row) state into slot ``idx`` of the stacked
    state. ``idx`` is traced, so admission into ANY slot reuses one
    compiled program — no shape change, no recompile."""
    return _write_row(stacked, row, jnp.asarray(idx, jnp.int32))


@jax.jit
def _read_row(stacked, idx):
    return jax.tree.map(
        lambda s: jax.lax.dynamic_slice_in_dim(s, idx, 1, axis=SLOT_AXIS),
        stacked)


def read_slot(stacked: Any, idx) -> Any:
    """Slice slot ``idx`` back out as a batch-1 per-row state."""
    return _read_row(stacked, jnp.asarray(idx, jnp.int32))


def unstack_states(stacked: Any, n_slots: int) -> list[Any]:
    return [read_slot(stacked, i) for i in range(n_slots)]
