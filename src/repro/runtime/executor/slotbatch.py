"""Stack / unstack decode-slot states into one slot-batched pytree.

The scheduler's decode slots used to be independent batch-1 states, stepped
one `jax.jit` dispatch each. Here they live as ONE stacked pytree whose
batch axis IS the slot axis. Two layouts cover the whole model zoo:

  * transformer / enc-dec: every decode-state leaf is laid out
    ``[L(layers), B(slots), ...]`` (``init_decode_state`` vmaps the
    per-layer init over layers, so layers lead and the batch rides
    second) — ``SLOT_AXIS == 1``. With the per-row cache layout
    (``attention.init_cache(per_row=True)``) each row carries its own KV
    length/positions, so rows decode at independent positions inside a
    single dispatch. Enc-dec states additionally carry the per-slot
    *extras bank*: the encoder-derived cross-attention K/V
    (``[L, B, Se, Hkv, hd]``) plus per-row cross positions
    (``[L, B, Se]``) — written row-wise by ``write_slot`` at admission
    (the encoder re-runs per request), so whisper slots consume their own
    encoder context inside the stacked layout. The bank stores DECODED
    (r-independent) values, so a planner ``set_code_r`` keeps it valid;
    the 2MR requeue path re-admits and therefore re-encodes it.
  * xLSTM: block state is positionless recurrent state whose leaves are
    ``[B(slots), ...]`` — the batch axis already leads (``slot axis 0``),
    no per-row position plumbing needed; the recurrence is independent
    per row, so stacking slots is exactly a vmap over the block state.

Slot admission overwrites one row in place with a traced index — same
shapes every time, never a recompile (``TRACES`` counts actual retraces;
the property tests pin it at one per state structure).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# default stacked layout: [L, B, ...] decode-state leaves, slots on axis 1
SLOT_AXIS = 1

# jit retrace counters (incremented at TRACE time only): the slot-isolation
# property tests assert admission into any slot of a warm bank reuses one
# compiled program per (structure, shapes, axis)
TRACES = {"write": 0, "read": 0}


def slot_axis(model) -> int:
    """Which leaf axis indexes slots for this family: 0 for xLSTM (block
    state has no leading layer axis), 1 ([L, B, ...]) for everything
    else."""
    return 0 if model.cfg.ssm_kind == "xlstm" else 1


def supports_slot_batching(model) -> bool:
    """Every zoo family slot-batches: decoder-only via per-row KV
    positions, enc-dec via the per-slot extras bank (per-request encoder
    state in the stacked layout), xLSTM via its positionless [B, ...]
    block state. Kept as an API point for the scheduler's auto mode."""
    return True


def blank_batch(model, n: int) -> dict:
    """Zero-filled per-request inputs shaping an all-empty pool (enc-dec:
    zero frames size the extras bank; real per-request frames land at
    admission)."""
    if model.cfg.is_encdec:
        return {"frames": jnp.zeros((n, model.cfg.enc_seq,
                                     model.cfg.d_model), jnp.float32)}
    return {}


def request_batch(prompt, extras: dict | None = None) -> dict:
    """One request's prefill batch: [1, S] tokens plus per-request extras
    broadcast to batch-1 leaves. The SINGLE layout both executors share —
    the sequential oracle and the batched admission path must not drift
    on exactly the shape the differential tests pin."""
    batch = {"tokens": np.asarray(prompt, np.int32)[None, :]}
    for key, val in (extras or {}).items():
        batch[key] = np.asarray(val)[None, ...]
    return batch


def blank_state(stepper, n_slots: int) -> Any:
    """A fresh stacked per-row decode state with ``n_slots`` rows.

    Built from ``eval_shape`` (zero device compute): admission overwrites
    a row WHOLESALE via ``write_slot`` before it is ever read, so only the
    shapes/dtypes matter — running the real init (for enc-dec, a full
    encoder forward over zeros per executor construction) would be pure
    waste. Never-admitted rows step through decode harmlessly, exactly as
    they did with the real init values."""
    shapes = jax.eval_shape(
        lambda p, b: stepper.model.init_decode(
            p, b, n_slots, stepper.max_len, stepper.cache_dtype,
            per_row=True),
        stepper.params, blank_batch(stepper.model, n_slots))
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)


def stack_states(states: list[Any], axis: int = SLOT_AXIS) -> Any:
    """Concatenate batch-1 per-row states along the slot axis."""
    return jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=axis), *states)


@functools.partial(jax.jit, static_argnames="axis")
def _write_row(stacked, row, idx, *, axis):
    TRACES["write"] += 1
    return jax.tree.map(
        lambda s, x: jax.lax.dynamic_update_slice_in_dim(
            s, x.astype(s.dtype), idx, axis=axis), stacked, row)


def write_slot(stacked: Any, idx, row: Any, axis: int = SLOT_AXIS) -> Any:
    """Write a (batch-1, per-row) state into slot ``idx`` of the stacked
    state. ``idx`` is traced, so admission into ANY slot reuses one
    compiled program — no shape change, no recompile."""
    return _write_row(stacked, row, jnp.asarray(idx, jnp.int32), axis=axis)


@functools.partial(jax.jit, static_argnames="axis")
def _read_row(stacked, idx, *, axis):
    TRACES["read"] += 1
    return jax.tree.map(
        lambda s: jax.lax.dynamic_slice_in_dim(s, idx, 1, axis=axis),
        stacked)


def read_slot(stacked: Any, idx, axis: int = SLOT_AXIS) -> Any:
    """Slice slot ``idx`` back out as a batch-1 per-row state."""
    return _read_row(stacked, jnp.asarray(idx, jnp.int32), axis=axis)


def unstack_states(stacked: Any, n_slots: int,
                   axis: int = SLOT_AXIS) -> list[Any]:
    return [read_slot(stacked, i, axis=axis) for i in range(n_slots)]
