"""Async device-pool executor: dispatch rounds, overlap host work, measure.

The pool owns the stacked slot state and turns a scheduler round into ONE
asynchronously dispatched device program. With ``overlap=True`` (the
serving default) it pipelines host and device: ``step_round`` dispatches
round N and only then blocks (``jax.block_until_ready``) on round N-1's
tokens — so the host-side admission/eviction/queue work of step N runs
while the device still computes round N-1, and a harvested completion
frees its slot for the next admission. ``overlap=False`` harvests the
round it just dispatched (exact sequential-scheduler semantics, used by
the equivalence tests and the legacy facade).

Every harvest records the MEASURED wall-clock dispatch->harvest time of
that round into ``RuntimeMetrics.round_ms``: with ``overlap=False`` that
is exactly the device dispatch->ready latency; with ``overlap=True`` it
is the pipelined ROUND PERIOD (device time plus whatever host work the
pipeline hid under it — the quantity whose inverse is sustained
rounds/sec). The scheduler keeps feeding the modelled ``StragglerModel``
numbers to the simulated clock, and ``RuntimeMetrics`` reports both
series side by side.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.tracer import NULL_RECORDER
from repro.runtime.executor.slotbatch import (blank_state, request_batch,
                                              slot_axis, write_slot)
from repro.runtime.executor.vstep import VStep


@dataclasses.dataclass(frozen=True)
class RoundHandle:
    """An in-flight round: its (async) token array, the (slot, tag) pairs
    active at dispatch time, and the dispatch timestamp. Tags identify the
    occupant a token belongs to — a slot re-admitted between dispatch and
    harvest must not inherit its predecessor's token."""
    toks: jax.Array               # [n_slots, 1] int32 (async)
    slots: tuple[tuple[int, Any], ...]
    t0: float
    variant: str = "reference"    # compiled program dispatched (vstep)
    round_idx: int = 0            # vstep dispatch id (matches the `round`
    #                               arg of this round's round.dispatch
    #                               event — the span flow-arrow anchor)


class SlotPoolExecutor:
    """Batched execution engine the continuous-batching scheduler drives."""

    def __init__(self, stepper, n_slots: int, *, overlap: bool = True,
                 use_fused: bool | str = "auto", metrics=None, tracer=None,
                 perf=None, profile: bool = False, spans=None):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.stepper = stepper
        self.slot_axis = slot_axis(stepper.model)
        self.n_slots = int(n_slots)
        self.overlap = bool(overlap)
        self.metrics = metrics
        self.tracer = tracer if tracer is not None else NULL_RECORDER
        # obs.perf.PerfMonitor | None: roofline attribution at first
        # harvest (+ after geometry changes), achieved rates every harvest
        self.perf = perf
        # obs.spans.SpanTracker | None: each harvest stamps the MEASURED
        # round period + unhidden block time onto the decode slices that
        # rode the round (matched by RoundHandle.round_idx)
        self.spans = spans
        # wrap each dispatch in a jax.profiler step annotation so an
        # enclosing jax.profiler.start_trace groups device work per round
        self.profile = bool(profile)
        self.vstep = VStep(stepper, use_fused=use_fused)
        self.state = blank_state(stepper, self.n_slots)
        self.last_toks = jnp.zeros((self.n_slots, 1), jnp.int32)
        self.active = np.zeros(self.n_slots, bool)
        self.tags: list[Any] = [None] * self.n_slots
        self._pending: RoundHandle | None = None
        # per-round injection hook point: fn(executor, valid) runs on the
        # host right before each dispatch (chaos harness: replay modelled
        # stalls into the MEASURED round series)
        self.round_hooks: list[Any] = []

    # ------------------------------------------------------------ slots ----
    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    def admit(self, slot: int, prompt, valid, tag: Any = None,
              extras: dict | None = None) -> int:
        """Prefill ``prompt`` into ``slot`` (a fresh per-row batch-1 state
        written over the stacked row — no recompile) and activate it.
        Returns the first generated token. ``tag`` identifies the occupant
        in harvested (slot, tag, token) triples. ``extras`` carries
        per-request batch inputs (enc-dec ``frames``): the encoder runs
        for this request and its cross-KV lands in the slot's row of the
        stacked extras bank."""
        logits, row = self.stepper.prefill(request_batch(prompt, extras),
                                           valid, per_row=True)
        tok = self.stepper.greedy(logits)                     # [1, 1]
        self.state = write_slot(self.state, slot, row, axis=self.slot_axis)
        self.last_toks = self.last_toks.at[slot].set(tok[0])
        self.active[slot] = True
        self.tags[slot] = tag
        return int(np.asarray(tok)[0, 0])

    def evict(self, slot: int):
        """Deactivate a slot: its row keeps static shape (and may keep
        stepping harmlessly until readmission overwrites it)."""
        self.active[slot] = False
        self.tags[slot] = None

    def evict_all(self):
        self.active[:] = False
        self.tags = [None] * self.n_slots

    def drop_pending(self):
        """Discard the in-flight round (2MR fallback: its occupants were
        requeued, their tokens must not be harvested)."""
        self._pending = None

    # ----------------------------------------------------------- rounds ----
    def _dispatch(self, valid) -> RoundHandle | None:
        if not self.active.any():
            return None
        t_host = time.perf_counter()
        for hook in self.round_hooks:
            hook(self, valid)
        if self.profile:
            with jax.profiler.StepTraceAnnotation(
                    "decode_round", step_num=self.vstep.n_dispatches):
                new_state, toks, _ = self.vstep.round(
                    self.state, self.last_toks, valid)
        else:
            new_state, toks, _ = self.vstep.round(self.state,
                                                  self.last_toks, valid)
        # state/toks advance at DISPATCH order: a later admit() writes its
        # row into this round's (async) output state, never a stale one.
        self.state, self.last_toks = new_state, toks
        occupants = tuple((int(i), self.tags[int(i)])
                          for i in np.flatnonzero(self.active))
        t0 = time.perf_counter()
        if self.tracer.enabled:
            self.tracer.emit(
                "round.dispatch", track="rounds",
                round=self.vstep.n_dispatches, n_active=len(occupants),
                dead=[int(i) for i in np.flatnonzero(
                    ~np.asarray(valid, bool))],
                wall_args={"dispatch_host_ms": (t0 - t_host) * 1e3})
        return RoundHandle(toks, occupants, t0, self.vstep.last_variant,
                           round_idx=self.vstep.n_dispatches)

    def _harvest(self, handle: RoundHandle | None
                 ) -> list[tuple[int, Any, int]]:
        if handle is None:
            return []
        t_block = time.perf_counter()
        jax.block_until_ready(handle.toks)
        t_ready = time.perf_counter()
        if self.metrics is not None:
            # dispatch->ready when harvesting synchronously; the pipelined
            # round period (host work hidden under device time) with overlap
            self.metrics.observe_round_ms((t_ready - handle.t0) * 1e3)
        if self.perf is not None:
            self.perf.observe_round(self, (t_ready - handle.t0) * 1e3,
                                    handle.variant)
        # overlap attribution: period = dispatch->ready wall span;
        # block = the device time NOT hidden by host work. Under
        # overlap, period - block is the admission/eviction/queue work
        # the pipeline successfully hid under device compute.
        period = (t_ready - handle.t0) * 1e3
        block = (t_ready - t_block) * 1e3
        if self.spans is not None:
            self.spans.on_round_wall(handle.round_idx, period, block)
        if self.tracer.enabled:
            self.tracer.emit(
                "round.harvest", track="rounds", overlap=self.overlap,
                n_harvested=len(handle.slots),
                wall_dur_ms=period,
                wall_args={"block_ms": block,
                           "host_overlapped_ms": period - block})
        arr = np.asarray(handle.toks)
        return [(s, tag, int(arr[s, 0])) for s, tag in handle.slots]

    def step_round(self, valid) -> list[tuple[int, Any, int]]:
        """Dispatch one round and return harvested (slot, tag, token)
        triples — the round just dispatched (overlap off) or the previous
        one (overlap on; the current round stays in flight while the host
        works)."""
        prev, self._pending = self._pending, None
        self._pending = self._dispatch(valid)
        if self.overlap:
            return self._harvest(prev)
        out = self._harvest(prev) + self._harvest(self._pending)
        self._pending = None
        return out
