"""Batched slot executor: the runtime's one-dispatch-per-round engine.

``slotbatch`` stacks the pool's decode slots into one pytree — per-slot
KV positions for transformers, a per-slot encoder extras bank for
enc-dec, positionless [B, ...] block state for xLSTM — ``vstep`` compiles
the single vectorised decode round (with the Pallas fused coded-head fast
path), and ``pool`` wraps both in an async executor that overlaps
host-side admission with device compute and measures real round latency.
The continuous-batching scheduler drives ``SlotPoolExecutor`` for EVERY
zoo architecture; per-slot sequential stepping survives only as the
differential-test oracle and the ``--sequential`` escape hatch.
"""
from repro.runtime.executor.pool import RoundHandle, SlotPoolExecutor
from repro.runtime.executor.slotbatch import (TRACES, blank_batch,
                                              blank_state, read_slot,
                                              request_batch, slot_axis,
                                              stack_states,
                                              supports_slot_batching,
                                              unstack_states, write_slot)
from repro.runtime.executor.vstep import VStep

__all__ = [
    "RoundHandle", "SlotPoolExecutor", "TRACES", "VStep",
    "blank_batch", "blank_state", "read_slot", "request_batch",
    "slot_axis", "stack_states", "supports_slot_batching",
    "unstack_states", "write_slot",
]
