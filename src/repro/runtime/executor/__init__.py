"""Batched slot executor: the runtime's one-dispatch-per-round engine.

``slotbatch`` stacks the pool's decode slots into one pytree with per-slot
KV positions, ``vstep`` compiles the single vectorised decode round (with
the Pallas fused coded-head fast path), and ``pool`` wraps both in an
async executor that overlaps host-side admission with device compute and
measures real round latency. The continuous-batching scheduler drives
``SlotPoolExecutor`` instead of stepping slots one by one.
"""
from repro.runtime.executor.pool import RoundHandle, SlotPoolExecutor
from repro.runtime.executor.slotbatch import (blank_state, read_slot,
                                              stack_states,
                                              supports_slot_batching,
                                              unstack_states, write_slot)
from repro.runtime.executor.vstep import VStep

__all__ = [
    "RoundHandle", "SlotPoolExecutor", "VStep",
    "blank_state", "read_slot", "stack_states", "supports_slot_batching",
    "unstack_states", "write_slot",
]
