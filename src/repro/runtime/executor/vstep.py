"""One jitted, vectorised decode round over the stacked slot state.

The whole slot pool advances one token in a SINGLE device dispatch for
EVERY zoo family: per-row KV positions let transformer slots attend at
their own offsets, the enc-dec extras bank gives each whisper slot its
own cross-attention context, and xLSTM rows advance their positionless
block state independently. The health controller's validity mask is
broadcast into every coded GEMM of the round, so an in-budget erasure is
recovered in-step for all slots at once (the paper's close-to-zero
recovery, now a pool-level property).

Two compiled variants exist, both traced exactly once:

  * reference — the model's coded decode returning full last-position
    logits (what the equivalence and erasure-sweep tests pin down);
  * fused     — the FULL-Pallas round: the model body runs with
    ``ctx.fused_body=True`` so every in-body coded GEMM (attention QKV,
    FFN up/gate) goes through ``kernels.cdc_matmul`` — shard GEMMs +
    Eq. 12 parity decode + merge in ONE kernel, per-shard outputs never
    materialised in HBM — and the final norm feeds the Pallas fused
    coded-head kernel (``kernels.cdc_decode``): head GEMM + parity
    decode + greedy argmax, logits never hitting HBM either.
    Valid for <= 1 erased shard (the in-register Eq. 12 regime); rounds
    beyond that fall back to the reference path — ``round()`` counts the
    host mask BEFORE dispatch, so a 2+-erasure round (in budget only for
    the dedicated layout) always gets the reference MDS decode, never a
    silent wrong answer. Off TPU the kernels run in Pallas interpret
    mode; ``use_fused="auto"`` therefore enables them only where they
    compile natively.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _fused_supported(stepper) -> bool:
    # arch-agnostic: every zoo family's decode exposes return_hidden and
    # ends in the same coded LM head, so the fused kernel only needs the
    # sum-parity generator row it consumes
    return (stepper.coded
            and bool(np.allclose(stepper.model.ctx.spec.code.generator[0],
                                 1.0)))


class VStep:
    """Owns the jitted round functions and their dispatch/trace counters.

    ``n_traces`` increments only when jit actually retraces — the
    executor tests assert it stays at one per variant while ``n_dispatches``
    grows with the rounds, i.e. the hot path is one compiled program.
    """

    def __init__(self, stepper, use_fused: bool | str = "auto"):
        self.stepper = stepper
        if use_fused == "auto":
            use_fused = (_fused_supported(stepper)
                         and jax.default_backend() == "tpu")
        self.use_fused = bool(use_fused) and _fused_supported(stepper)
        self.n_traces = 0
        self.n_dispatches = 0
        # which compiled program the LAST round() call dispatched — the
        # perf monitor attributes each harvested round to its variant
        self.last_variant = "reference"

        # closures read stepper.model at TRACE time: a planner-driven
        # set_code_r swaps the coded context, its new parity shapes key a
        # fresh trace, and that trace must see the new geometry
        def _round(params, state, toks, valid):
            self.n_traces += 1
            logits, new_state = stepper.model.decode(params, state, toks,
                                                     valid)
            last = logits[:, -1:]
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
            return new_state, nxt, last

        self._round = jax.jit(_round)

        def _round_fused(params, state, toks, valid, w_shards, parity_w):
            self.n_traces += 1
            # fused-body context: every in-body coded GEMM of this trace
            # goes through the fused Pallas kernel (cdc_matmul). Built at
            # trace time from the CURRENT model so set_code_r retraces
            # with the new geometry, like the reference closure.
            model = stepper.model
            fm = dataclasses.replace(
                model, ctx=dataclasses.replace(model.ctx, fused_body=True))
            hidden, new_state = fm.decode(params, state, toks, valid,
                                          return_hidden=True)
            tok, _ = ops.fused_head_argmax(
                hidden[:, -1, :].astype(jnp.float32), w_shards, parity_w,
                valid, vocab=stepper.model.cfg.vocab)
            return new_state, tok[:, None]

        self._round_fused = jax.jit(_round_fused)
        self._head_cache: tuple[int, Any, Any] | None = None

    # ----------------------------------------------------------- fused ----
    def _head_shards(self):
        """[T, k, m_l] column shards + sum-parity weight of the LM head,
        cached per params object (refreshed by re-encode)."""
        params = self.stepper.params
        if self._head_cache is None or self._head_cache[0] != id(params):
            w = params["lm_head"]["w"]
            k, m = w.shape
            t = self.stepper.n_shards
            w_shards = jnp.moveaxis(w.reshape(k, t, m // t), 1, 0)
            self._head_cache = (id(params), w_shards, w_shards.sum(0))
        return self._head_cache[1], self._head_cache[2]

    # ----------------------------------------------------------- rounds ----
    def round(self, state, toks, valid) -> tuple[Any, jax.Array,
                                                 jax.Array | None]:
        """One decode round over the stacked state. valid: [T] bool host
        mask. Returns (new_state, next_toks [n,1], last_logits or None
        when the fused head skipped materialising them)."""
        st = self.stepper
        v = st._mask(valid) if st.coded else None
        self.n_dispatches += 1
        if self.use_fused and v is not None \
                and int(st.n_shards - np.asarray(valid).sum()) <= 1:
            self.last_variant = "fused"
            w_shards, parity_w = self._head_shards()
            new_state, nxt = self._round_fused(st.params, state, toks, v,
                                               w_shards, parity_w)
            return new_state, nxt, None
        self.last_variant = "reference"
        return self._round(st.params, state, toks, v)
