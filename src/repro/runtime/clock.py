"""Clocks for the coded cluster runtime.

All runtime timing is in **milliseconds** (matching ``core.failure``'s
latency models). The scheduler never calls ``time`` directly — it asks a
clock, so tests and benchmarks drive a deterministic ``SimClock`` while a
live deployment can plug in ``WallClock`` without touching scheduling
logic.
"""
from __future__ import annotations

import time
from typing import Protocol, runtime_checkable


@runtime_checkable
class Clock(Protocol):
    def now(self) -> float:
        """Current time in milliseconds."""
        ...


class SimClock:
    """Deterministic simulated clock, advanced explicitly by the runtime."""

    def __init__(self, start_ms: float = 0.0):
        self._now = float(start_ms)

    def now(self) -> float:
        return self._now

    def advance(self, dt_ms: float) -> float:
        if dt_ms < 0:
            raise ValueError(f"cannot advance clock by {dt_ms} ms")
        self._now += float(dt_ms)
        return self._now

    def advance_to(self, t_ms: float) -> float:
        """Jump forward to ``t_ms`` (no-op if already past it)."""
        self._now = max(self._now, float(t_ms))
        return self._now


class WallClock:
    """Monotonic wall time in ms (for live serving, not used by tests)."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return (time.monotonic() - self._t0) * 1e3
