"""Shard-health controller: live validity masks + the CDC+2MR hybrid.

Consumes erasure/recovery events (``core.failure``'s erasure-channel view
of hardware) and decides, per event, which half of the paper's §6.3 hybrid
policy applies:

  * within the code's erasure budget  -> flip the validity mask and keep
    decoding; the coded GEMMs recover in-step (CDC path, close-to-zero
    recovery, §5.2);
  * beyond the budget (or a whole-replica failure) -> the 2MR fallback:
    in-flight requests are requeued, the shard set is replaced by the
    standby replica (heal-all), and parity weights are re-encoded offline;
  * shard recovery -> heal the shard and re-encode parity so the restored
    device rejoins the code.

The budget comes from the code geometry (``CodedDenseSpec.
max_device_failures``) and is only granted when the model's split method
is CDC-suitable per ``core.policy`` Table 1 — input-split layers cannot be
protected offline, so their runtime budget is zero regardless of r.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core.policy import OUTPUT_SPLIT, SplitMethod


class EventKind(enum.Enum):
    ERASURE = "erasure"                  # one shard's output lost
    RECOVERY = "recovery"                # a dead shard came back
    REPLICA_FAILURE = "replica_failure"  # whole serving replica lost


class HealthAction(enum.Enum):
    CONTINUE = "continue"    # mask updated; coded math absorbs the loss
    REQUEUE = "requeue"      # beyond budget: 2MR fallback, drain + heal
    REENCODE = "reencode"    # healed: parity weights must be re-encoded
    NOOP = "noop"            # duplicate report; state already reflects it


@dataclasses.dataclass(frozen=True, order=True)
class ShardEvent:
    time_ms: float
    kind: EventKind = dataclasses.field(compare=False)
    shard: int = dataclasses.field(default=-1, compare=False)


def erasure(time_ms: float, shard: int) -> ShardEvent:
    return ShardEvent(time_ms, EventKind.ERASURE, shard)


def recovery(time_ms: float, shard: int) -> ShardEvent:
    return ShardEvent(time_ms, EventKind.RECOVERY, shard)


def replica_failure(time_ms: float) -> ShardEvent:
    return ShardEvent(time_ms, EventKind.REPLICA_FAILURE)


class ShardHealthController:
    def __init__(self, n_shards: int, budget: int,
                 split: SplitMethod = OUTPUT_SPLIT,
                 events: list[ShardEvent] | None = None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.split = split
        # Table 1 gate: an unsuitable split cannot carry offline parity, so
        # every failure is beyond-budget no matter how many parity shards
        # were provisioned.
        self.budget = budget if split.suitable_for_cdc else 0
        self.valid = np.ones(n_shards, bool)
        self._pending: list[ShardEvent] = sorted(events or [])
        self.log: list[tuple[ShardEvent, HealthAction]] = []
        # observers (e.g. ``obs.ShardTimeline``): notified of every applied
        # event (``on_health(ev, action, mask)``) and of replica swaps
        # (``on_heal_all(t_ms, healed_shards, mask)``) — the single source
        # of truth for per-shard health timelines
        self.observers: list = []
        # high-water mark of concurrent dead shards since the last drain —
        # a beyond-budget burst heals in the same round (replace_replica),
        # so per-round mask sampling alone would never see it; the
        # adaptive planner drains this per estimation window
        self.peak_dead = 0

    # ----------------------------------------------------------- events ----
    def schedule(self, event: ShardEvent):
        self._pending.append(event)
        self._pending.sort()

    def poll(self, now_ms: float) -> list[HealthAction]:
        """Apply every pending event due at or before ``now_ms``."""
        return [a for _, a in self.poll_events(now_ms)]

    def poll_events(self, now_ms: float
                    ) -> list[tuple[ShardEvent, HealthAction]]:
        """Like ``poll`` but keeps the event paired with its action, so
        callers (the scheduler's tracer wiring) can attribute each action
        to the shard that caused it."""
        out = []
        while self._pending and self._pending[0].time_ms <= now_ms:
            ev = self._pending.pop(0)
            out.append((ev, self.apply(ev)))
        return out

    def apply(self, ev: ShardEvent) -> HealthAction:
        if ev.kind is EventKind.ERASURE:
            if not (0 <= ev.shard < self.n_shards):
                raise ValueError(f"shard {ev.shard} out of range")
            if not self.valid[ev.shard]:
                # duplicate report of an already-dead shard: one physical
                # failure must count (and be recovered) exactly once
                action = HealthAction.NOOP
            else:
                self.valid[ev.shard] = False
                n_dead = int((~self.valid).sum())
                self.peak_dead = max(self.peak_dead, n_dead)
                action = (HealthAction.CONTINUE if n_dead <= self.budget
                          else HealthAction.REQUEUE)
        elif ev.kind is EventKind.RECOVERY:
            if self.valid[ev.shard]:
                action = HealthAction.NOOP
            else:
                self.valid[ev.shard] = True
                action = HealthAction.REENCODE
        elif ev.kind is EventKind.REPLICA_FAILURE:
            action = HealthAction.REQUEUE
        else:  # pragma: no cover
            raise ValueError(ev.kind)
        self.log.append((ev, action))
        for obs in self.observers:
            obs.on_health(ev, action, self.valid)
        return action

    # ---------------------------------------------------------- healing ----
    def set_budget(self, budget: int):
        """Re-size the erasure budget (adaptive redundancy planner entry).
        The Table-1 gate still applies: an unsuitable split keeps budget 0
        no matter what the planner provisions."""
        if budget < 0:
            raise ValueError(f"budget must be >= 0, got {budget}")
        self.budget = int(budget) if self.split.suitable_for_cdc else 0

    def replace_replica(self, t_ms: float | None = None) -> int:
        """2MR path: swap in the standby, all shards healthy again.

        ``t_ms`` timestamps the swap for health observers (per-shard
        down-interval closure); omitted, observers see the time of the
        last applied event. Returns the number of shards that were dead
        before the swap.
        """
        healed = [int(s) for s in np.flatnonzero(~self.valid)]
        self.valid[:] = True
        if t_ms is None:
            t_ms = self.log[-1][0].time_ms if self.log else 0.0
        for obs in self.observers:
            obs.on_heal_all(float(t_ms), healed, self.valid)
        return len(healed)

    def drain_peak_dead(self) -> int:
        """Return the concurrent-dead high-water mark since the previous
        drain and re-arm it at the current state."""
        peak, self.peak_dead = self.peak_dead, self.n_dead
        return peak

    @property
    def mask(self) -> np.ndarray:
        return self.valid.copy()

    @property
    def n_dead(self) -> int:
        return int((~self.valid).sum())

    # ------------------------------------------------- mesh placement ----
    # Under dist.sharding, coded shard i IS model-rank i: weight columns
    # [i*m_l, (i+1)*m_l) and folded parity slot i live on the devices at
    # index i of the mesh's `model` axis (one device per (pod, data)
    # replica). These helpers translate the controller's logical mask into
    # that physical placement, so erasure events can name real devices and
    # the runtime can report which hardware a CONTINUE is absorbing.

    def _model_axis(self, mesh, axis: str):
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no {axis!r} axis: "
                             f"{tuple(mesh.axis_names)}")
        if mesh.shape[axis] != self.n_shards:
            raise ValueError(
                f"mesh {axis!r} size {mesh.shape[axis]} != "
                f"n_shards {self.n_shards}: shard<->device map undefined")
        return list(mesh.axis_names).index(axis)

    def shard_devices(self, mesh, axis: str = "model") -> dict[int, tuple]:
        """shard i -> the mesh devices holding it (one per data replica)."""
        ax = self._model_axis(mesh, axis)
        devs = np.moveaxis(np.asarray(mesh.devices), ax, 0)
        return {i: tuple(devs[i].ravel()) for i in range(self.n_shards)}

    def device_mask(self, mesh, axis: str = "model") -> np.ndarray:
        """Validity broadcast onto mesh.devices' shape (True = healthy)."""
        ax = self._model_axis(mesh, axis)
        shape = [1] * np.asarray(mesh.devices).ndim
        shape[ax] = self.n_shards
        return np.broadcast_to(
            self.valid.reshape(shape), np.asarray(mesh.devices).shape
        ).copy()

    def dead_devices(self, mesh, axis: str = "model") -> tuple:
        """Flat tuple of mesh devices currently erased, placement order."""
        by_shard = self.shard_devices(mesh, axis)
        out = []
        for i in np.flatnonzero(~self.valid):
            out.extend(by_shard[int(i)])
        return tuple(out)
