"""Runtime telemetry: counters, bounded histograms, queue-depth stats.

Everything the benchmarks report comes through here, snapshotted as plain
JSON-serialisable dicts so ``benchmarks/serve_throughput.py`` (and any
external collector) can diff coded vs uncoded runs without touching
runtime internals.

Memory is BOUNDED regardless of run length: the former unbounded
``latencies_ms``/``queueing_ms``/``round_ms``/``queue_depth`` lists are
now fixed-bucket log-spaced histograms (exact n/mean/max running
aggregates + Prometheus-exportable bucket counts) with a deterministic
bounded reservoir for percentiles. Up to the reservoir size the
percentiles are EXACT (so every existing CI assertion and
``BENCH_*.json`` schema is unchanged — same ``p50_ms``/``p99_ms``/
``mean_ms``/``max_ms`` keys); beyond it they are reservoir estimates,
reproducible across replays because sampling uses a per-instance seeded
stream (Vitter's algorithm R), never global randomness.

Counter names are a closed registry: ``count()`` on an unknown name
raises instead of silently creating a phantom counter (a typo like
``requests_complete`` used to vanish into the report); extensions go
through an explicit ``register()``.

TTFT (arrival -> first generated token, simulated clock) is a
first-class distribution alongside request latency: the ROADMAP's
chunked-prefill item gates on TTFT p99, and this is its baseline.
"""
from __future__ import annotations

import json
from collections import deque

import numpy as np

_COUNTERS = (
    "requests_submitted",
    "requests_admitted",
    "requests_completed",
    "requests_requeued",
    "requests_shed",
    "decode_rounds",
    "tokens_generated",
    "erasures_recovered",
    "beyond_budget_failures",
    "shards_healed",
    "parity_reencodes",
    "faults_injected",
    "replans",
)

#: default reservoir bound — small runs (every test/benchmark in CI) stay
#: exact; week-long runs stay O(1) in memory.
RESERVOIR_SIZE = 4096
#: log-spaced bucket upper bounds, 10 µs .. 1000 s: covers fused-round
#: microseconds through chaos-storm requeue latencies.
BUCKET_BOUNDS = tuple(float(b) for b in np.geomspace(1e-2, 1e6, 49))


class Histogram:
    """Fixed-bucket histogram + deterministic bounded reservoir.

    ``observe`` is O(log buckets); ``n``/``total``/``vmax`` are exact
    running aggregates, ``percentile`` comes from the reservoir (exact
    while ``n <= reservoir_size``). ``buckets()`` yields cumulative
    (upper_bound, count) pairs in Prometheus ``le`` convention.
    """

    def __init__(self, reservoir_size: int = RESERVOIR_SIZE,
                 bounds: tuple = BUCKET_BOUNDS, seed: int = 0):
        if reservoir_size < 1:
            raise ValueError("reservoir_size must be >= 1")
        self.bounds = np.asarray(bounds, np.float64)
        if self.bounds.ndim != 1 or not np.all(np.diff(self.bounds) > 0):
            raise ValueError("bounds must be strictly increasing 1-D")
        self.counts = np.zeros(self.bounds.size + 1, np.int64)  # +overflow
        self.reservoir_size = int(reservoir_size)
        self._res = np.empty(self.reservoir_size, np.float64)
        self._rng = np.random.default_rng(seed)
        self.n = 0
        self.total = 0.0
        self.vmax = -np.inf
        self.vmin = np.inf

    def observe(self, x: float):
        x = float(x)
        self.n += 1
        self.total += x
        self.vmax = max(self.vmax, x)
        self.vmin = min(self.vmin, x)
        self.counts[int(np.searchsorted(self.bounds, x, side="left"))] += 1
        if self.n <= self.reservoir_size:
            self._res[self.n - 1] = x
        else:
            # Vitter's algorithm R: uniform over the stream, deterministic
            # per instance (seeded stream, no global RNG)
            j = int(self._rng.integers(self.n))
            if j < self.reservoir_size:
                self._res[j] = x

    # ------------------------------------------------------------- read ----
    def __len__(self) -> int:
        return self.n

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def _sample(self) -> np.ndarray:
        return self._res[:min(self.n, self.reservoir_size)]

    def percentile(self, q: float) -> float:
        if self.n == 0:
            raise ValueError("empty histogram")
        return float(np.percentile(self._sample(), q))

    def buckets(self):
        """Cumulative (le, count) pairs; the last le is +Inf."""
        cum = np.cumsum(self.counts)
        for le, c in zip(self.bounds, cum[:-1]):
            yield float(le), int(c)
        yield float("inf"), int(cum[-1])

    def dist(self) -> dict:
        """The snapshot dict — keys unchanged from the unbounded-list
        implementation so BENCH_*.json schemas and CI assertions hold."""
        if self.n == 0:
            return {"n": 0}
        return {
            "n": self.n,
            "mean_ms": self.mean,
            "p50_ms": self.percentile(50),
            "p99_ms": self.percentile(99),
            "max_ms": float(self.vmax),
        }


class QueueDepthStats:
    """Running queue-depth aggregates (formerly an unbounded
    (t_ms, depth) list): exact sample count / mean / max plus the last
    observed depth for live gauges."""

    def __init__(self):
        self.n = 0
        self.total = 0
        self.vmax = 0
        self.last = 0

    def sample(self, t_ms: float, depth: int):
        depth = int(depth)
        self.n += 1
        self.total += depth
        self.vmax = max(self.vmax, depth)
        self.last = depth

    def snapshot(self) -> dict:
        return {
            "samples": self.n,
            "mean": self.total / self.n if self.n else 0.0,
            "max": self.vmax,
        }


class RuntimeMetrics:
    #: plans kept verbatim for the snapshot's r-series; bounded so a
    #: perpetual server cannot grow it without limit
    PLAN_LOG_BOUND = 4096

    def __init__(self, reservoir_size: int = RESERVOIR_SIZE):
        self.counters: dict[str, int] = {k: 0 for k in _COUNTERS}
        self.latencies_ms = Histogram(reservoir_size, seed=1)
        self.queueing_ms = Histogram(reservoir_size, seed=2)
        self.ttft_ms = Histogram(reservoir_size, seed=3)
        self.round_ms = Histogram(reservoir_size, seed=4)  # MEASURED rounds
        self.queue_depth = QueueDepthStats()
        # roofline-anchored perf gauges (obs.perf merges static attribution
        # + per-round achieved rates here; empty when perf accounting is
        # off) — exported via prometheus_text as repro_perf_* gauges
        self.perf: dict = {}
        # per-cause shed breakdown (reason -> count); the total stays in
        # counters["requests_shed"] so existing BENCH schemas are unchanged
        self.shed_causes: dict[str, int] = {}
        self.plan_log: deque[dict] = deque(maxlen=self.PLAN_LOG_BOUND)
        self.start_ms: float | None = None
        self.end_ms: float | None = None

    # ------------------------------------------------------------ write ----
    def register(self, name: str):
        """Add a counter to the registry (extension point). Registering
        an existing name is a no-op, never a reset."""
        self.counters.setdefault(name, 0)

    def count(self, name: str, n: int = 1):
        if name not in self.counters:
            raise KeyError(
                f"unknown counter {name!r}: register() it first "
                f"(known: {sorted(self.counters)})")
        self.counters[name] += n

    def count_shed(self, cause: str):
        """One shed request, attributed to a cause (the admission queue's
        ``shed_reason``). Keeps the aggregate counter in step."""
        self.count("requests_shed")
        self.shed_causes[cause] = self.shed_causes.get(cause, 0) + 1

    def observe_request(self, latency_ms: float, queueing_ms: float,
                        ttft_ms: float | None = None):
        self.latencies_ms.observe(latency_ms)
        self.queueing_ms.observe(queueing_ms)
        if ttft_ms is not None:
            self.ttft_ms.observe(ttft_ms)

    def observe_round_ms(self, wall_ms: float):
        """Measured wall-clock time of one decode round (dispatch->ready,
        or the pipelined round period under executor overlap) — the
        real-hardware series reported alongside the modelled
        StragglerModel numbers that drive the simulated clock."""
        self.round_ms.observe(wall_ms)

    def sample_queue_depth(self, t_ms: float, depth: int):
        self.queue_depth.sample(t_ms, depth)

    def set_perf(self, values: dict):
        """Merge perf-attribution gauges (latest-value semantics)."""
        self.perf.update(values)

    def observe_plan(self, plan: dict, applied: bool):
        """One adaptive-redundancy planner decision (window boundary)."""
        self.plan_log.append({"applied": bool(applied), **plan})

    def mark(self, t_ms: float):
        if self.start_ms is None:
            self.start_ms = float(t_ms)
        self.end_ms = float(t_ms)

    # ------------------------------------------------------------- read ----
    @property
    def elapsed_ms(self) -> float:
        if self.start_ms is None or self.end_ms is None:
            return 0.0
        return self.end_ms - self.start_ms

    def snapshot(self) -> dict:
        elapsed_s = self.elapsed_ms / 1e3
        return {
            "counters": dict(self.counters),
            "shed_causes": dict(self.shed_causes),
            "elapsed_ms": self.elapsed_ms,
            "throughput": {
                "tokens_per_s": (self.counters["tokens_generated"] / elapsed_s
                                 if elapsed_s > 0 else None),
                "requests_per_s": (
                    self.counters["requests_completed"] / elapsed_s
                    if elapsed_s > 0 else None),
            },
            "request_latency": self.latencies_ms.dist(),
            "queueing_delay": self.queueing_ms.dist(),
            "ttft": self.ttft_ms.dist(),
            "round_latency_measured": self.round_ms.dist(),
            "queue_depth": self.queue_depth.snapshot(),
            "perf": dict(self.perf),
            "planner": {
                "n_plans": len(self.plan_log),
                "r_series": [[p["t_ms"], p["r"]] for p in self.plan_log],
                "final_r": (self.plan_log[-1]["r"] if self.plan_log
                            else None),
                "max_r": (max(p["r"] for p in self.plan_log)
                          if self.plan_log else None),
                "plans": list(self.plan_log),
            },
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)
