"""Runtime telemetry: counters, latency histograms, queue-depth series.

Everything the benchmarks report comes through here, snapshotted as plain
JSON-serialisable dicts so ``benchmarks/serve_throughput.py`` (and any
external collector) can diff coded vs uncoded runs without touching
runtime internals.
"""
from __future__ import annotations

import json

import numpy as np

_COUNTERS = (
    "requests_submitted",
    "requests_admitted",
    "requests_completed",
    "requests_requeued",
    "requests_shed",
    "decode_rounds",
    "tokens_generated",
    "erasures_recovered",
    "beyond_budget_failures",
    "shards_healed",
    "parity_reencodes",
    "faults_injected",
    "replans",
)


class RuntimeMetrics:
    def __init__(self):
        self.counters: dict[str, int] = {k: 0 for k in _COUNTERS}
        self.latencies_ms: list[float] = []
        self.queueing_ms: list[float] = []
        self.round_ms: list[float] = []       # MEASURED wall-clock rounds
        self.queue_depth: list[tuple[float, int]] = []   # (t_ms, depth)
        self.plan_log: list[dict] = []        # adaptive-redundancy plans
        self.start_ms: float | None = None
        self.end_ms: float | None = None

    # ------------------------------------------------------------ write ----
    def count(self, name: str, n: int = 1):
        self.counters[name] = self.counters.get(name, 0) + n

    def observe_request(self, latency_ms: float, queueing_ms: float):
        self.latencies_ms.append(float(latency_ms))
        self.queueing_ms.append(float(queueing_ms))

    def observe_round_ms(self, wall_ms: float):
        """Measured wall-clock time of one decode round (dispatch->ready,
        or the pipelined round period under executor overlap) — the
        real-hardware series reported alongside the modelled
        StragglerModel numbers that drive the simulated clock."""
        self.round_ms.append(float(wall_ms))

    def sample_queue_depth(self, t_ms: float, depth: int):
        self.queue_depth.append((float(t_ms), int(depth)))

    def observe_plan(self, plan: dict, applied: bool):
        """One adaptive-redundancy planner decision (window boundary)."""
        self.plan_log.append({"applied": bool(applied), **plan})

    def mark(self, t_ms: float):
        if self.start_ms is None:
            self.start_ms = float(t_ms)
        self.end_ms = float(t_ms)

    # ------------------------------------------------------------- read ----
    @property
    def elapsed_ms(self) -> float:
        if self.start_ms is None or self.end_ms is None:
            return 0.0
        return self.end_ms - self.start_ms

    def _dist(self, xs: list[float]) -> dict:
        if not xs:
            return {"n": 0}
        a = np.asarray(xs, np.float64)
        return {
            "n": int(a.size),
            "mean_ms": float(a.mean()),
            "p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99)),
            "max_ms": float(a.max()),
        }

    def snapshot(self) -> dict:
        elapsed_s = self.elapsed_ms / 1e3
        depths = [d for _, d in self.queue_depth]
        return {
            "counters": dict(self.counters),
            "elapsed_ms": self.elapsed_ms,
            "throughput": {
                "tokens_per_s": (self.counters["tokens_generated"] / elapsed_s
                                 if elapsed_s > 0 else None),
                "requests_per_s": (
                    self.counters["requests_completed"] / elapsed_s
                    if elapsed_s > 0 else None),
            },
            "request_latency": self._dist(self.latencies_ms),
            "queueing_delay": self._dist(self.queueing_ms),
            "round_latency_measured": self._dist(self.round_ms),
            "queue_depth": {
                "samples": len(depths),
                "mean": float(np.mean(depths)) if depths else 0.0,
                "max": int(max(depths)) if depths else 0,
            },
            "planner": {
                "n_plans": len(self.plan_log),
                "r_series": [[p["t_ms"], p["r"]] for p in self.plan_log],
                "final_r": (self.plan_log[-1]["r"] if self.plan_log
                            else None),
                "max_r": (max(p["r"] for p in self.plan_log)
                          if self.plan_log else None),
                "plans": list(self.plan_log),
            },
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)
