"""Priority / SLO-aware admission queue for the coded cluster runtime.

Admission order is by (priority desc, deadline asc, arrival asc, rid):
with no deadlines or priorities set this degenerates to exact FIFO, and a
request requeued by the 2MR fallback (which keeps its original arrival
time) naturally re-enters ahead of later arrivals — the same ordering the
old deque gave, now as one total order that deadlines and priorities can
bend.

Shedding: with a ``max_depth`` bound, pushing into a full queue drops the
WORST-ordered sheddable request (the incoming one, if it sorts last)
instead of growing without bound — deadline-aware tail drop. Requests
that were ever admitted (``n_requeues > 0``: the 2MR fallback put them
back) are NEVER shed — neither at their own force-push nor as the victim
of a later push — preserving the paper's "never loses a request" claim
for admitted work; the queue may exceed the bound by the number of such
protected requests.
"""
from __future__ import annotations

import bisect

from repro.runtime.request import Request


def _key(req: Request):
    deadline = req.deadline_ms if req.deadline_ms is not None else float("inf")
    return (-req.priority, deadline, req.arrival_ms, req.rid)


def _protected(req: Request) -> bool:
    return req.n_requeues > 0


class AdmissionQueue:
    def __init__(self, max_depth: int | None = None, spans=None, clock=None):
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self.max_depth = max_depth
        # span emission point: shedding is the queue's decision, so the
        # queue stamps the reason and terminates the victim's span tree
        # (obs.spans.SpanTracker | None; clock supplies the sim stamp)
        self.spans = spans
        self.clock = clock
        self._q: list[tuple[tuple, Request]] = []    # sorted by key

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self):
        return (req for _, req in self._q)

    def push(self, req: Request, force: bool = False) -> Request | None:
        """Insert ``req``; returns the request shed by the depth bound (the
        worst-ordered sheddable one — possibly ``req`` itself), or None."""
        bisect.insort(self._q, (_key(req), req))
        if force or self.max_depth is None or len(self._q) <= self.max_depth:
            return None
        for i in range(len(self._q) - 1, -1, -1):
            if not _protected(self._q[i][1]):
                victim = self._q.pop(i)[1]
                victim.shed_reason = "queue_full" if victim is req \
                    else "displaced"
                if self.spans is not None:
                    t = self.clock.now() if self.clock is not None \
                        else victim.arrival_ms
                    self.spans.on_shed(victim, t, victim.shed_reason)
                return victim
        return None    # every entry is in-flight work put back by 2MR

    def pop(self) -> Request:
        """Earliest-deadline (then FIFO) request."""
        return self._q.pop(0)[1]

    def peek(self) -> Request:
        return self._q[0][1]
