"""Request objects flowing through the coded cluster runtime.

A request is a prompt plus a token budget. The scheduler owns all state
transitions; the paper's operational claim — "the system never loses a
request" — means every submitted request terminates in COMPLETED, possibly
after one or more requeues through the 2MR fallback path (§6.3).
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    COMPLETED = "completed"
    SHED = "shed"                       # dropped by the queue-depth bound


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [prompt_len] int32 token ids
    max_new_tokens: int
    arrival_ms: float = 0.0
    deadline_ms: float | None = None    # SLO deadline (None = best effort)
    priority: int = 0                   # higher pops first
    extras: dict | None = None          # extra per-request batch fields,
    #                                     unbatched (e.g. enc-dec "frames"
    #                                     [enc_seq, D]); admission adds the
    #                                     leading batch axis

    # -- mutated by the scheduler ------------------------------------------
    state: RequestState = RequestState.QUEUED
    tokens: list[int] = dataclasses.field(default_factory=list)
    slot: int | None = None
    admitted_ms: float | None = None
    first_token_ms: float | None = None
    finished_ms: float | None = None
    n_requeues: int = 0
    shed_reason: str | None = None      # stamped by the admission queue:
    #                                     "queue_full" (arrived into a full
    #                                     queue, sorted last) | "displaced"
    #                                     (a better-ordered arrival pushed
    #                                     it out)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new_tokens

    @property
    def latency_ms(self) -> float | None:
        """Submit-to-last-token latency (includes queueing + requeues)."""
        if self.finished_ms is None:
            return None
        return self.finished_ms - self.arrival_ms

    @property
    def queueing_ms(self) -> float | None:
        """Time spent queued before the (final) admission."""
        if self.admitted_ms is None:
            return None
        return self.admitted_ms - self.arrival_ms

    @property
    def ttft_ms(self) -> float | None:
        """Time to first token: arrival -> first token of the SURVIVING
        run (a 2MR requeue discards partial progress, so the stamp resets
        with it — TTFT then includes the full requeue delay, which is
        what an SLO sees)."""
        if self.first_token_ms is None:
            return None
        return self.first_token_ms - self.arrival_ms

    def reset_for_requeue(self):
        """Discard partial progress; the request goes back to the queue.

        CDC recovery never takes this path — it is the 2MR half of the
        hybrid policy, for failures beyond the code's erasure budget.

        ``first_token_ms`` resets with the progress (TTFT then includes
        the full requeue delay); span state resets with it — the
        scheduler's ``SpanTracker.on_requeue`` closes the discarded
        decode episode and opens a ``fault_recovery`` span at the same
        instant, so the span tree and the stamps never disagree.
        """
        self.state = RequestState.QUEUED
        self.tokens = []
        self.slot = None
        self.admitted_ms = None
        self.first_token_ms = None
        self.n_requeues += 1
