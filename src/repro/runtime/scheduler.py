"""Continuous-batching scheduler over a fixed pool of decode slots.

The paper's serving claims are single-request statements ("never loses a
request", close-to-zero recovery). This scheduler turns them into
steady-state properties of a request STREAM:

  * a deadline-aware admission queue (FIFO when no deadlines/priorities
    are set) feeds ``n_slots`` decode slots; a slot (its [1, max_len]
    KV-cache row) is reused by the next queued request the moment its
    occupant finishes — continuous batching, no wait-for-the-whole-batch
    barrier. A configurable queue-depth bound sheds the worst-ordered
    request instead of queueing without bound;
  * every decode round consults the ``ShardHealthController``: within the
    erasure budget the round proceeds with the flipped validity mask and
    the coded GEMMs reconstruct the lost shard in-step (CDC half of the
    §6.3 hybrid); beyond budget, in-flight requests are requeued, the
    standby replica is swapped in, and parity is re-encoded offline (2MR
    half) — the request stream drains either way, so no admitted request
    is lost;
  * time comes from an injected clock. Tests use a deterministic
    ``SimClock`` advanced by a fixed per-round latency; benchmarks sample
    round latency from the paper's first-T-of-(T+r) straggler model. The
    MEASURED wall-clock latency of every real round is recorded alongside
    (``RuntimeMetrics.round_ms``).

Execution: by default the slot pool lives in a ``SlotPoolExecutor`` — one
stacked state (per-slot KV positions; enc-dec adds the per-slot encoder
extras bank; xLSTM stacks its positionless block state), ONE jitted
dispatch per round for the whole pool, optional host/device overlap —
for EVERY zoo architecture. ``batched=False`` keeps the original
sequential per-slot stepping over batch-1 states as the
differential-test oracle and ``--sequential`` escape hatch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import numpy as np

from repro.core.failure import StragglerModel, request_latency
from repro.core.seeds import stream_rng
from repro.obs.shardlog import ShardTimeline
from repro.obs.tracer import NULL_RECORDER, FlightRecorder
from repro.runtime.clock import Clock, SimClock
from repro.runtime.executor import (SlotPoolExecutor, request_batch,
                                    supports_slot_batching)
from repro.runtime.health import HealthAction, ShardHealthController
from repro.runtime.metrics import RuntimeMetrics
from repro.runtime.queue import AdmissionQueue
from repro.runtime.request import Request, RequestState
from repro.serve.engine import ModelStepper


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    n_slots: int = 4
    step_time_ms: float = 1.0        # fixed per-round latency (SimClock)
    straggler: StragglerModel | None = None  # sample round latency instead
    seed: int = 0
    max_requeues: int = 8            # liveness guard for event storms
    max_rounds: int = 100_000
    batched: bool | None = None      # None: auto (batched when supported)
    overlap: bool = True             # pipeline host work with device rounds
    use_fused: bool | str = "auto"   # full-Pallas round: fused in-body
    #                                  coded GEMM+decode kernels + fused head
    max_queue_depth: int | None = None   # shed beyond this depth
    perf: bool = False               # roofline attribution + achieved rates
    profile: bool = False            # jax.profiler step annotations per round
    spans: bool = True               # per-request span trees (obs.spans);
    #                                  bounded memory, on by default like
    #                                  the shard timeline

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if self.step_time_ms < 0:
            raise ValueError("step_time_ms must be >= 0")
        if self.max_requeues < 0 or self.max_rounds < 1:
            raise ValueError("max_requeues/max_rounds out of range")
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError("max_queue_depth must be >= 1")


@dataclasses.dataclass
class _Slot:
    idx: int
    request: Request | None = None
    state: Any = None                # sequential path: batch-1 decode state
    last_tok: Any = None
    occupancies: int = 0

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatchingScheduler:
    def __init__(self, stepper: ModelStepper, rcfg: RuntimeConfig,
                 clock: Clock | None = None,
                 health: ShardHealthController | None = None,
                 metrics: RuntimeMetrics | None = None,
                 latency: Any = None,
                 tracer: FlightRecorder | None = None):
        self.stepper = stepper
        self.rcfg = rcfg
        self.clock = clock if clock is not None else SimClock()
        self.health = health if health is not None else ShardHealthController(
            stepper.n_shards, stepper.erasure_budget)
        self.metrics = metrics if metrics is not None else RuntimeMetrics()
        # flight recorder (repro.obs): the default NULL_RECORDER makes
        # every emission a single disabled-branch — zero events recorded
        self.tracer = tracer if tracer is not None else NULL_RECORDER
        self.tracer.bind_clock(self.clock)
        if self.tracer.enabled and not stepper.tracer.enabled:
            # adopt the stepper so code.resize lands in this stream too
            stepper.tracer = self.tracer
        # per-shard health timeline: always on (O(1) per health event);
        # the SAME source of truth the planner's window stats approximate
        self.shardlog = ShardTimeline(stepper.n_shards,
                                      t0_ms=self.clock.now())
        self.health.observers.append(self.shardlog)
        # per-request span trees (obs.spans): queue_wait -> prefill ->
        # decode (per-round slices + stall) -> fault_recovery, gap-free
        # over every request lifetime; always on by default (bounded ring,
        # SimClock-primary stamps) — obs.slo decomposes them into
        # TTFT/TPOT breakdowns and deadline-miss attribution
        self.spans = None
        if rcfg.spans:
            from repro.obs.spans import SpanTracker
            self.spans = SpanTracker()
        self.queue = AdmissionQueue(max_depth=rcfg.max_queue_depth,
                                    spans=self.spans, clock=self.clock)
        self.slots = [_Slot(i) for i in range(rcfg.n_slots)]
        self.completed: list[Request] = []
        self.shed: list[Request] = []
        # rcfg.seed is the run's ROOT seed: every stochastic component
        # (modelled stragglers here, the fault injector, the injected
        # latency process) derives an independent stream from it, so a
        # chaos run reproduces bit-exact from one number.
        self._rng = stream_rng(rcfg.seed, "straggler")
        self._next_rid = 0
        # faults.InjectedLatency (or anything with .round_ms): replaces the
        # plain StragglerModel draw for the simulated clock advance
        self.latency = latency
        # per-round injection hook point: fn(scheduler) runs at the top of
        # every round, before health events apply (chaos injector, adaptive
        # redundancy planner attach here)
        self.round_hooks: list[Any] = []

        batched = rcfg.batched
        if batched is None:
            batched = supports_slot_batching(stepper.model)
        self.executor: SlotPoolExecutor | None = None
        if batched:
            perf = None
            if rcfg.perf:
                # roofline-anchored round attribution: costed at first
                # harvest, achieved rates + counter-track events per round
                from repro.obs.perf import PerfMonitor
                perf = PerfMonitor(metrics=self.metrics, tracer=self.tracer)
            self.executor = SlotPoolExecutor(
                stepper, rcfg.n_slots, overlap=rcfg.overlap,
                use_fused=rcfg.use_fused, metrics=self.metrics,
                tracer=self.tracer, perf=perf, profile=rcfg.profile,
                spans=self.spans)

    # --------------------------------------------------------- ingestion ----
    def submit(self, prompt, max_new_tokens: int,
               arrival_ms: float | None = None,
               deadline_ms: float | None = None,
               priority: int = 0, extras: dict | None = None) -> Request:
        """Enqueue a request. ``arrival_ms`` lets timed workloads record
        the TRUE arrival instant even when submission happens at the next
        round boundary (latency then includes the sub-round wait); it must
        not lie in the future. ``deadline_ms``/``priority`` bend the
        admission order (earliest deadline / highest priority first); a
        full queue sheds the worst-ordered request. ``extras`` carries
        per-request batch fields (enc-dec ``frames``): both executors
        thread them into prefill — the batched path writes the resulting
        encoder state into the slot's row of the stacked extras bank."""
        now = self.clock.now()
        arrival = now if arrival_ms is None else min(float(arrival_ms), now)
        req = Request(self._next_rid, np.asarray(prompt, np.int32),
                      int(max_new_tokens), arrival_ms=arrival,
                      deadline_ms=deadline_ms, priority=priority,
                      extras=extras)
        self._next_rid += 1
        self.metrics.count("requests_submitted")
        if self.tracer.enabled:
            self.tracer.emit("request.submit", track="requests", t_ms=now,
                             rid=req.rid, prompt_len=int(req.prompt.size),
                             max_new_tokens=req.max_new_tokens,
                             deadline_ms=deadline_ms, priority=priority)
        if self.spans is not None:
            # before push: if the depth bound sheds req itself the queue
            # terminates a tree that must already exist
            self.spans.on_submit(req)
        victim = self.queue.push(req)
        if victim is not None:
            victim.state = RequestState.SHED
            self.shed.append(victim)
            self.metrics.count_shed(victim.shed_reason or "queue_full")
            if self.tracer.enabled:
                self.tracer.emit("request.shed", track="requests",
                                 rid=victim.rid, shed_by=req.rid,
                                 reason=victim.shed_reason,
                                 queue_depth=len(self.queue))
        self.metrics.sample_queue_depth(self.clock.now(), len(self.queue))
        return req

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(not s.free for s in self.slots)

    @property
    def n_running(self) -> int:
        return sum(not s.free for s in self.slots)

    # ------------------------------------------------------------ health ----
    def _handle_health(self):
        traced = self.tracer.enabled
        for ev, action in self.health.poll_events(self.clock.now()):
            track = f"shard:{ev.shard}" if ev.shard >= 0 else "rounds"
            if action is HealthAction.CONTINUE:
                # CDC path: mask flipped, decode recovers in-step.
                self.metrics.count("erasures_recovered")
                if traced:
                    self.tracer.emit("fault.recovered", track=track,
                                     t_ms=ev.time_ms, shard=ev.shard,
                                     n_dead=self.health.n_dead,
                                     budget=self.health.budget)
            elif action is HealthAction.REQUEUE:
                if traced:
                    self.tracer.emit("fault.beyond_budget", track=track,
                                     t_ms=ev.time_ms, shard=ev.shard,
                                     fault=ev.kind.value,
                                     n_dead=self.health.n_dead,
                                     budget=self.health.budget)
                self._requeue_inflight(ev)
            elif action is HealthAction.REENCODE:
                # a shard rejoined: fold it back into the code.
                self.metrics.count("shards_healed")
                if traced:
                    self.tracer.emit("shard.heal", track=track,
                                     t_ms=ev.time_ms, shard=ev.shard,
                                     cause="recovery")
                self._reencode()
            elif traced:
                # duplicate report: resolve the injected fault explicitly
                # so every fault.inject has a terminal trace event
                self.tracer.emit("fault.noop", track=track,
                                 t_ms=ev.time_ms, shard=ev.shard,
                                 fault=ev.kind.value)

    def _reencode(self):
        """Offline parity re-encode + its telemetry (single emit point)."""
        self.stepper.reencode()
        self.metrics.count("parity_reencodes")
        self.shardlog.on_reencode(self.clock.now())
        if self.tracer.enabled:
            self.tracer.emit("code.reencode", track="rounds",
                             r=int(self.stepper.model.ctx.code_r)
                             if self.stepper.coded else 0)
        if self.spans is not None:
            # heal_wait child on every open fault_recovery span (no-op on
            # shard-rejoin re-encodes with nothing requeued)
            self.spans.on_heal(
                self.clock.now(),
                reencode_wall_ms=self.stepper.last_reencode_wall_ms)

    def _requeue_inflight(self, ev=None):
        """2MR fallback: drain slots, swap the standby replica in, re-encode
        parity. Requests keep their original arrival order; shedding never
        applies to in-flight work. ``ev`` is the beyond-budget health event
        that triggered the fallback — span trees carry its identity so the
        trace exporter can draw the fault_recovery -> injector erasure
        flow arrow."""
        self.metrics.count("beyond_budget_failures")
        fault = None
        if ev is not None:
            fault = {"fault_shard": int(ev.shard),
                     "fault_t_ms": float(ev.time_ms),
                     "fault_kind": ev.kind.value}
        if self.executor is not None:
            # in-flight round (if any) was computed for requeued occupants
            self.executor.drop_pending()
            self.executor.evict_all()
        victims = []
        for slot in self.slots:
            if slot.free:
                continue
            req = slot.request
            if req.n_requeues >= self.rcfg.max_requeues:
                raise RuntimeError(
                    f"request {req.rid} exceeded max_requeues="
                    f"{self.rcfg.max_requeues}; the event schedule never "
                    "leaves a healthy window to finish in")
            req.reset_for_requeue()
            victims.append(req)
            if self.spans is not None:
                # resets with first_token_ms: the wasted decode episode
                # closes, a fault_recovery span opens at the same stamp
                self.spans.on_requeue(req, self.clock.now(), fault=fault)
            if self.tracer.enabled:
                self.tracer.emit("request.requeue", track=f"slot:{slot.idx}",
                                 rid=req.rid, n_requeues=req.n_requeues)
            slot.request, slot.state, slot.last_tok = None, None, None
        for req in victims:
            self.queue.push(req, force=True)
        self.metrics.count("requests_requeued", len(victims))
        healed = self.health.replace_replica(self.clock.now())
        self.metrics.count("shards_healed", healed)
        if self.tracer.enabled:
            self.tracer.emit("shard.heal_all", track="rounds",
                             healed=healed, requeued=len(victims))
        self._reencode()

    # --------------------------------------------------------- admission ----
    def _admit(self):
        mask = self.health.mask
        for slot in self.slots:
            if not slot.free or not self.queue:
                continue
            req = self.queue.pop()
            now = self.clock.now()
            req.state = RequestState.RUNNING
            req.slot = slot.idx
            req.admitted_ms = now
            if self.executor is not None:
                tok = self.executor.admit(slot.idx, req.prompt, mask,
                                          tag=req.rid, extras=req.extras)
                slot.request = req
            else:
                logits, state = self.stepper.prefill(
                    request_batch(req.prompt, req.extras), mask)
                t = self.stepper.greedy(logits)
                slot.request, slot.state, slot.last_tok = req, state, t
                tok = int(np.asarray(t)[0, 0])
            slot.occupancies += 1
            req.tokens.append(tok)
            req.first_token_ms = now
            if self.spans is not None:
                self.spans.on_admit(
                    req, now,
                    prefill_wall_ms=self.stepper.last_prefill_wall_ms)
            self.metrics.count("requests_admitted")
            self.metrics.count("tokens_generated")
            if self.tracer.enabled:
                self.tracer.emit("request.admit", track=f"slot:{slot.idx}",
                                 t_ms=now, rid=req.rid,
                                 queueing_ms=req.queueing_ms,
                                 n_requeues=req.n_requeues)
                self.tracer.emit("request.first_token",
                                 track=f"slot:{slot.idx}", t_ms=now,
                                 rid=req.rid, ttft_ms=req.ttft_ms)
            if req.done:
                self._complete(slot)

    def _complete(self, slot: _Slot):
        req = slot.request
        req.state = RequestState.COMPLETED
        req.finished_ms = self.clock.now()
        self.completed.append(req)
        if self.spans is not None:
            self.spans.on_complete(req, req.finished_ms)
        self.metrics.count("requests_completed")
        self.metrics.observe_request(req.latency_ms, req.queueing_ms,
                                     ttft_ms=req.ttft_ms)
        if self.tracer.enabled:
            # span over the slot occupancy: admit -> last token
            self.tracer.emit("request.complete", track=f"slot:{slot.idx}",
                             t_ms=req.admitted_ms,
                             dur_ms=req.finished_ms - req.admitted_ms,
                             rid=req.rid, n_tokens=len(req.tokens),
                             latency_ms=req.latency_ms,
                             ttft_ms=req.ttft_ms,
                             n_requeues=req.n_requeues)
        # the slot (and its KV-cache row) is immediately reusable
        slot.request, slot.state, slot.last_tok = None, None, None
        if self.executor is not None:
            self.executor.evict(slot.idx)

    # -------------------------------------------------------------- step ----
    def step(self) -> list[Request]:
        """One decode round: run injection hooks (chaos injector, adaptive
        planner), apply due health events, admit into free slots, decode
        one token per occupied slot — one jitted dispatch for the whole
        pool on the batched path — and advance the clock."""
        self.metrics.mark(self.clock.now())
        for hook in self.round_hooks:
            hook(self)
        self._handle_health()
        self._admit()

        if self.executor is not None:
            finished = self._step_batched()
        else:
            finished = self._step_sequential()

        self.metrics.count("decode_rounds")
        self._advance_clock()
        self.metrics.sample_queue_depth(self.clock.now(), len(self.queue))
        self.metrics.mark(self.clock.now())
        return finished

    def _step_batched(self) -> list[Request]:
        finished: list[Request] = []
        ready = self.executor.step_round(self.health.mask)
        for slot_idx, rid, tok in ready:
            slot = self.slots[slot_idx]
            # stale harvest: occupant changed (completed/requeued) between
            # dispatch and harvest, or already hit its token budget
            if slot.free or slot.request.rid != rid or slot.request.done:
                continue
            slot.request.tokens.append(tok)
            self.metrics.count("tokens_generated")
            if slot.request.done:
                finished.append(slot.request)
                self._complete(slot)
        return finished

    def _step_sequential(self) -> list[Request]:
        finished: list[Request] = []
        mask = self.health.mask
        t0 = time.perf_counter()
        stepped = False
        for slot in self.slots:
            if slot.free or slot.request.done:
                continue
            logits, slot.state = self.stepper.decode_one(
                slot.state, slot.last_tok, mask)
            slot.last_tok = self.stepper.greedy(logits)
            slot.request.tokens.append(int(np.asarray(slot.last_tok)[0, 0]))
            stepped = True
            self.metrics.count("tokens_generated")
            if slot.request.done:
                finished.append(slot.request)
                self._complete(slot)
        if stepped:
            # np.asarray above synced every dispatch: this is the real
            # n-dispatch round latency the batched path collapses
            self.metrics.observe_round_ms((time.perf_counter() - t0) * 1e3)
        return finished

    def _round_latency(self) -> tuple[float, float]:
        """(dt, stall) of the round that just ran: the simulated-clock
        advance plus the deterministic straggler/fault excess over a
        fault-free round (0 outside the injected-latency path — the plain
        StragglerModel draw and the fixed step time model no fault)."""
        T, r = self.stepper.n_shards, 0
        if self.stepper.coded:
            r = int(self.stepper.model.ctx.code_r)
        if self.latency is not None:
            # injected latency: same fault schedule as the health events
            dt = self.latency.round_ms(self.clock.now(), T, r,
                                       mask=self.health.mask)
            return dt, float(getattr(self.latency, "last_stall_ms", 0.0))
        if self.rcfg.straggler is not None:
            times = self.rcfg.straggler.sample(self._rng, (T + r,))
            # coded rounds finish at the T-th of T+r arrivals; uncoded
            # rounds wait for all T shards (paper §6.2)
            dt = float(request_latency(times, T)) if r \
                else float(times[:T].max())
            return dt, 0.0
        return self.rcfg.step_time_ms, 0.0

    def _round_id(self) -> int:
        """Id of the round this step ran: the executor's dispatch counter
        on the batched path (matches the ``round`` arg of its
        round.dispatch event), the decode_rounds counter otherwise."""
        if self.executor is not None:
            return self.executor.vstep.n_dispatches
        return self.metrics.counters["decode_rounds"]

    def _advance_clock(self):
        if not isinstance(self.clock, SimClock):
            return
        dt, stall = self._round_latency()
        if self.spans is not None:
            # decode slices tile each occupancy: [now, now + dt] for every
            # slot still occupied after this round's harvest (a request
            # completed or requeued this round already closed its decode
            # span at `now`, which is exactly where its last slice ended)
            now = self.clock.now()
            ridx = self._round_id()
            for slot in self.slots:
                if not slot.free:
                    self.spans.on_round(slot.request.rid, now, dt, ridx,
                                        stall_ms=stall)
        self.clock.advance(dt)

    # --------------------------------------------------------------- run ----
    def run(self) -> list[Request]:
        """Drain queue + slots. Returns all requests completed so far."""
        rounds = 0
        while self.busy:
            self.step()
            rounds += 1
            if rounds > self.rcfg.max_rounds:
                raise RuntimeError(
                    f"scheduler did not drain in {self.rcfg.max_rounds} "
                    "rounds")
        return self.completed


def run_arrivals(sched: ContinuousBatchingScheduler,
                 arrivals: list[tuple]) -> list[Request]:
    """Drive a timed workload: ``arrivals`` is [(time_ms, prompt,
    max_new_tokens)] with an optional 4th ``extras`` dict per entry
    (enc-dec ``frames``). Requests are submitted when the (simulated)
    clock reaches their arrival time; idle gaps fast-forward the clock."""
    pending = deque(sorted(arrivals, key=lambda a: a[0]))
    rounds = 0
    while pending or sched.busy:
        if pending and not sched.busy and \
                pending[0][0] > sched.clock.now() and \
                isinstance(sched.clock, SimClock):
            sched.clock.advance_to(pending[0][0])
        while pending and pending[0][0] <= sched.clock.now():
            t, prompt, n, *rest = pending.popleft()
            sched.submit(prompt, n, arrival_ms=t,
                         extras=rest[0] if rest else None)
        sched.step()
        rounds += 1
        if rounds > sched.rcfg.max_rounds:
            raise RuntimeError(
                f"workload did not drain in {sched.rcfg.max_rounds} rounds")
    return sched.completed
