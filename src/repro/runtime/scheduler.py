"""Continuous-batching scheduler over a fixed pool of decode slots.

The paper's serving claims are single-request statements ("never loses a
request", close-to-zero recovery). This scheduler turns them into
steady-state properties of a request STREAM:

  * a FIFO admission queue feeds ``n_slots`` decode slots; a slot (its
    [1, max_len] KV-cache allocation) is reused by the next queued request
    the moment its occupant finishes — continuous batching, no
    wait-for-the-whole-batch barrier;
  * every decode round consults the ``ShardHealthController``: within the
    erasure budget the round proceeds with the flipped validity mask and
    the coded GEMMs reconstruct the lost shard in-step (CDC half of the
    §6.3 hybrid); beyond budget, in-flight requests are requeued, the
    standby replica is swapped in, and parity is re-encoded offline (2MR
    half) — the request stream drains either way, so no request is lost;
  * time comes from an injected clock. Tests use a deterministic
    ``SimClock`` advanced by a fixed per-round latency; benchmarks sample
    round latency from the paper's first-T-of-(T+r) straggler model.

Decode slots hold independent batch-1 states over ONE jitted step
function, so admission and completion never force a recompile and a
mid-stream erasure needs no re-dispatch.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import numpy as np

from repro.core.failure import StragglerModel, request_latency
from repro.runtime.clock import Clock, SimClock
from repro.runtime.health import HealthAction, ShardHealthController
from repro.runtime.metrics import RuntimeMetrics
from repro.runtime.request import Request, RequestState
from repro.serve.engine import ModelStepper


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    n_slots: int = 4
    step_time_ms: float = 1.0        # fixed per-round latency (SimClock)
    straggler: StragglerModel | None = None  # sample round latency instead
    seed: int = 0
    max_requeues: int = 8            # liveness guard for event storms
    max_rounds: int = 100_000

    def __post_init__(self):
        if self.n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {self.n_slots}")
        if self.step_time_ms < 0:
            raise ValueError("step_time_ms must be >= 0")
        if self.max_requeues < 0 or self.max_rounds < 1:
            raise ValueError("max_requeues/max_rounds out of range")


@dataclasses.dataclass
class _Slot:
    idx: int
    request: Request | None = None
    state: Any = None                # the slot's decode/KV state (batch=1)
    last_tok: Any = None
    occupancies: int = 0

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatchingScheduler:
    def __init__(self, stepper: ModelStepper, rcfg: RuntimeConfig,
                 clock: Clock | None = None,
                 health: ShardHealthController | None = None,
                 metrics: RuntimeMetrics | None = None):
        self.stepper = stepper
        self.rcfg = rcfg
        self.clock = clock if clock is not None else SimClock()
        self.health = health if health is not None else ShardHealthController(
            stepper.n_shards, stepper.erasure_budget)
        self.metrics = metrics if metrics is not None else RuntimeMetrics()
        self.queue: deque[Request] = deque()
        self.slots = [_Slot(i) for i in range(rcfg.n_slots)]
        self.completed: list[Request] = []
        self._rng = np.random.default_rng(rcfg.seed)
        self._next_rid = 0

    # --------------------------------------------------------- ingestion ----
    def submit(self, prompt, max_new_tokens: int,
               arrival_ms: float | None = None) -> Request:
        """Enqueue a request. ``arrival_ms`` lets timed workloads record
        the TRUE arrival instant even when submission happens at the next
        round boundary (latency then includes the sub-round wait); it must
        not lie in the future."""
        now = self.clock.now()
        arrival = now if arrival_ms is None else min(float(arrival_ms), now)
        req = Request(self._next_rid, np.asarray(prompt, np.int32),
                      int(max_new_tokens), arrival_ms=arrival)
        self._next_rid += 1
        self.queue.append(req)
        self.metrics.count("requests_submitted")
        self.metrics.sample_queue_depth(self.clock.now(), len(self.queue))
        return req

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(not s.free for s in self.slots)

    @property
    def n_running(self) -> int:
        return sum(not s.free for s in self.slots)

    # ------------------------------------------------------------ health ----
    def _handle_health(self):
        for action in self.health.poll(self.clock.now()):
            if action is HealthAction.CONTINUE:
                # CDC path: mask flipped, decode recovers in-step.
                self.metrics.count("erasures_recovered")
            elif action is HealthAction.REQUEUE:
                self._requeue_inflight()
            elif action is HealthAction.REENCODE:
                # a shard rejoined: fold it back into the code.
                self.metrics.count("shards_healed")
                self.stepper.reencode()
                self.metrics.count("parity_reencodes")
            # HealthAction.NOOP: duplicate report, nothing to do

    def _requeue_inflight(self):
        """2MR fallback: drain slots, swap the standby replica in, re-encode
        parity. Requests keep their original arrival order."""
        self.metrics.count("beyond_budget_failures")
        victims = []
        for slot in self.slots:
            if slot.free:
                continue
            req = slot.request
            if req.n_requeues >= self.rcfg.max_requeues:
                raise RuntimeError(
                    f"request {req.rid} exceeded max_requeues="
                    f"{self.rcfg.max_requeues}; the event schedule never "
                    "leaves a healthy window to finish in")
            req.reset_for_requeue()
            victims.append(req)
            slot.request, slot.state, slot.last_tok = None, None, None
        for req in sorted(victims, key=lambda r: (r.arrival_ms, r.rid),
                          reverse=True):
            self.queue.appendleft(req)
        self.metrics.count("requests_requeued", len(victims))
        healed = self.health.replace_replica()
        self.metrics.count("shards_healed", healed)
        self.stepper.reencode()
        self.metrics.count("parity_reencodes")

    # --------------------------------------------------------- admission ----
    def _admit(self):
        for slot in self.slots:
            if not slot.free or not self.queue:
                continue
            req = self.queue.popleft()
            now = self.clock.now()
            req.state = RequestState.RUNNING
            req.slot = slot.idx
            req.admitted_ms = now
            batch = {"tokens": req.prompt[None, :]}
            logits, state = self.stepper.prefill(batch, self.health.mask)
            tok = self.stepper.greedy(logits)
            slot.request, slot.state, slot.last_tok = req, state, tok
            slot.occupancies += 1
            req.tokens.append(int(np.asarray(tok)[0, 0]))
            self.metrics.count("requests_admitted")
            self.metrics.count("tokens_generated")
            if req.done:
                self._complete(slot)

    def _complete(self, slot: _Slot):
        req = slot.request
        req.state = RequestState.COMPLETED
        req.finished_ms = self.clock.now()
        self.completed.append(req)
        self.metrics.count("requests_completed")
        self.metrics.observe_request(req.latency_ms, req.queueing_ms)
        # the slot (and its KV allocation) is immediately reusable
        slot.request, slot.state, slot.last_tok = None, None, None

    # -------------------------------------------------------------- step ----
    def step(self) -> list[Request]:
        """One decode round: apply due health events, admit into free slots,
        decode one token per occupied slot, advance the clock."""
        self.metrics.mark(self.clock.now())
        self._handle_health()
        self._admit()

        finished: list[Request] = []
        mask = self.health.mask
        for slot in self.slots:
            if slot.free or slot.request.done:
                continue
            logits, slot.state = self.stepper.decode_one(
                slot.state, slot.last_tok, mask)
            slot.last_tok = self.stepper.greedy(logits)
            slot.request.tokens.append(int(np.asarray(slot.last_tok)[0, 0]))
            self.metrics.count("tokens_generated")
            if slot.request.done:
                finished.append(slot.request)
                self._complete(slot)

        self.metrics.count("decode_rounds")
        self._advance_clock()
        self.metrics.sample_queue_depth(self.clock.now(), len(self.queue))
        self.metrics.mark(self.clock.now())
        return finished

    def _advance_clock(self):
        if not isinstance(self.clock, SimClock):
            return
        if self.rcfg.straggler is not None:
            T, r = self.stepper.n_shards, 0
            if self.stepper.coded:
                r = int(self.stepper.model.ctx.code_r)
            times = self.rcfg.straggler.sample(self._rng, (T + r,))
            # coded rounds finish at the T-th of T+r arrivals; uncoded
            # rounds wait for all T shards (paper §6.2)
            dt = float(request_latency(times, T)) if r \
                else float(times[:T].max())
        else:
            dt = self.rcfg.step_time_ms
        self.clock.advance(dt)

    # --------------------------------------------------------------- run ----
    def run(self) -> list[Request]:
        """Drain queue + slots. Returns all requests completed so far."""
        rounds = 0
        while self.busy:
            self.step()
            rounds += 1
            if rounds > self.rcfg.max_rounds:
                raise RuntimeError(
                    f"scheduler did not drain in {self.rcfg.max_rounds} "
                    "rounds")
        return self.completed


def run_arrivals(sched: ContinuousBatchingScheduler,
                 arrivals: list[tuple[float, Any, int]]) -> list[Request]:
    """Drive a timed workload: ``arrivals`` is [(time_ms, prompt,
    max_new_tokens)]. Requests are submitted when the (simulated) clock
    reaches their arrival time; idle gaps fast-forward the clock."""
    pending = deque(sorted(arrivals, key=lambda a: a[0]))
    rounds = 0
    while pending or sched.busy:
        if pending and not sched.busy and \
                pending[0][0] > sched.clock.now() and \
                isinstance(sched.clock, SimClock):
            sched.clock.advance_to(pending[0][0])
        while pending and pending[0][0] <= sched.clock.now():
            t, prompt, n = pending.popleft()
            sched.submit(prompt, n, arrival_ms=t)
        sched.step()
        rounds += 1
        if rounds > sched.rcfg.max_rounds:
            raise RuntimeError(
                f"workload did not drain in {sched.rcfg.max_rounds} rounds")
    return sched.completed
