"""Decoder-only LM assembly: dense / MoE / hybrid(attn+mamba) / xLSTM.

Layers are stacked on a leading [L, ...] axis and driven by jax.lax.scan
(one layer traced once => small HLO, fast multi-hundred-layer compiles, and
the natural structure for FSDP gather-per-layer and pipeline stages).
xLSTM is heterogeneous (mLSTM/sLSTM mix) and unrolls instead.

Every model function takes the TPCtx (TP size / coded mode / mesh) and an
optional ``valid`` erasure mask — the CDC failure channel threads through the
whole forward pass to every coded GEMM.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import mamba as mamba_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (Params, TPCtx, col_dense, linear_init,
                                 rmsnorm, rmsnorm_init)


def _remat(f, policy: str = "full"):
    if policy == "none":
        return f
    if policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(f, policy=pol)
    return jax.checkpoint(f)


# --------------------------------------------------------------- layers ----

def xlstm_block_kinds(cfg) -> list[str]:
    """Static mLSTM/sLSTM schedule (every ``slstm_every``-th block is sLSTM;
    xLSTM[7:1] for the 125m config). Derived from cfg, never stored in the
    param pytree."""
    kinds = []
    for i in range(cfg.n_layers):
        if cfg.slstm_every and (i + 1) % cfg.slstm_every == 0:
            kinds.append("slstm")
        else:
            kinds.append("mlstm")
    return kinds


def _layer_init(key, cfg, ctx: TPCtx, dtype) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model),
                 "attn": attn_mod.attn_init(ks[0], cfg, ctx, dtype)}
    if cfg.family == "hybrid":
        p["mamba"] = mamba_mod.mamba_init(ks[1], cfg, ctx, dtype)
    if cfg.n_experts:
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["moe"] = ffn_mod.moe_init(ks[2], cfg, ctx, dtype)
    elif cfg.d_ff:
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["ffn"] = ffn_mod.ffn_init(ks[3], cfg, ctx, dtype)
    return p


def _layer_fwd(cfg, ctx: TPCtx, p: Params, x, valid, cache, mamba_state,
               pos_offset, q_chunk, kv_chunk):
    """One transformer block. Returns (x, new_cache, new_mamba_state)."""
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, new_cache = attn_mod.attention(
        ctx, p["attn"], cfg, xn, valid=valid, cache=cache,
        pos_offset=pos_offset, q_chunk=q_chunk, kv_chunk=kv_chunk)
    new_ms = mamba_state
    if cfg.family == "hybrid":
        m, new_ms = mamba_mod.mamba(ctx, p["mamba"], cfg, xn, valid,
                                    mamba_state)
        a = (a + m) * 0.5
    x = x + a
    if cfg.n_experts:
        x = x + ffn_mod.moe(ctx, p["moe"],
                            cfg, rmsnorm(p["ln2"], x, cfg.norm_eps), valid)
    elif cfg.d_ff:
        x = x + ffn_mod.ffn(ctx, p["ffn"],
                            cfg, rmsnorm(p["ln2"], x, cfg.norm_eps), valid)
    return x, new_cache, new_ms


# ---------------------------------------------------------------- model ----

def init_params(cfg, key, ctx: TPCtx, dtype=jnp.float32) -> Params:
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    d = cfg.d_model
    vocab_pad = ctx.pad_dim(cfg.vocab)
    params: Params = {
        "embed": (jax.random.normal(k_emb, (vocab_pad, d), jnp.float32)
                  * 0.02).astype(dtype),
        "ln_f": rmsnorm_init(d),
        "lm_head": linear_init(k_head, d, cfg.vocab, ctx, dtype,
                               scale=1.0 / d ** 0.5),
    }
    if cfg.ssm_kind == "xlstm":
        blocks = []
        for i, kind in enumerate(xlstm_block_kinds(cfg)):
            kb = jax.random.fold_in(k_layers, i)
            init = xlstm_mod.slstm_init if kind == "slstm" \
                else xlstm_mod.mlstm_init
            blocks.append(init(kb, cfg, ctx, dtype))
        params["blocks"] = blocks
    else:
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _layer_init(k, cfg, ctx, dtype))(keys)
    return params


def forward(cfg, params: Params, ctx: TPCtx, tokens: jax.Array,
            valid: jax.Array | None = None, *, remat: str = "full",
            q_chunk: int = 512, kv_chunk: int = 1024) -> jax.Array:
    """tokens: [B, S] int32 -> logits [B, S, vocab] (fp32)."""
    x = params["embed"][tokens].astype(params["embed"].dtype)
    x = ctx.shard_act(x)

    if cfg.ssm_kind == "xlstm":
        for kind, p in zip(xlstm_block_kinds(cfg), params["blocks"]):
            fn = xlstm_mod.mlstm if kind == "mlstm" else xlstm_mod.slstm
            x, _ = _remat(lambda x, p, fn=fn: fn(ctx, p, cfg, x, valid),
                          remat)(x, p)
    else:
        def body(x, p):
            y, _, _ = _layer_fwd(cfg, ctx, p, x, valid, None, None, 0,
                                 q_chunk, kv_chunk)
            return y, None

        x, _ = jax.lax.scan(_remat(body, remat), x, params["layers"])

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = col_dense(ctx, params["lm_head"], x, cfg.vocab, valid)
    return logits.astype(jnp.float32)


# --------------------------------------------------------------- decode ----

def init_decode_state(cfg, ctx: TPCtx, batch: int, max_len: int,
                      dtype=jnp.bfloat16, per_row: bool = False) -> Params:
    """``per_row=True`` builds the slot-batched layout: the KV cache length
    is a per-row position vector ([B] per layer) instead of one scalar, so
    rows decode at independent positions in a single dispatch and slot
    admission rewrites one row in place without recompiling."""
    state: Params = {}
    if cfg.ssm_kind == "xlstm":
        # xLSTM block state is positionless recurrent state with the batch
        # axis leading every leaf ([B, nh, ...]) — the batch axis IS the
        # slot axis, so per_row needs no extra plumbing: the executor
        # stacks/overwrites rows along axis 0 and the vmapped-over-batch
        # recurrence keeps rows independent.
        st = []
        for kind in xlstm_block_kinds(cfg):
            init = xlstm_mod.init_slstm_state if kind == "slstm" \
                else xlstm_mod.init_mlstm_state
            st.append(init(cfg, batch))
        state["blocks"] = st
        return state

    def one(_):
        return attn_mod.init_cache(cfg, batch, max_len, dtype, tp=ctx.tp,
                                   per_row=per_row)

    state["kv"] = jax.vmap(one)(jnp.arange(cfg.n_layers))
    if cfg.family == "hybrid":
        state["mamba"] = jax.vmap(
            lambda _: mamba_mod.init_mamba_state(cfg, batch, dtype))(
            jnp.arange(cfg.n_layers))
    return state


def decode_step(cfg, params: Params, ctx: TPCtx, state: Params,
                tokens: jax.Array, valid: jax.Array | None = None,
                *, kv_chunk: int = 1024, last_only: bool = False,
                return_hidden: bool = False
                ) -> tuple[jax.Array, Params]:
    """tokens: [B, s] (s=1 for pure decode) -> (logits [B, s, V], state).

    last_only: compute logits for the final position only (prefill returns
    the cache + one logit row; computing [B, 32k, 150k] logits would be
    hundreds of GB of dead temps).
    return_hidden: skip the LM head and return the post-ln_f hidden states
    instead of logits — the batched executor fuses head GEMM + parity
    decode + argmax into one Pallas kernel (kernels.cdc_decode)."""
    x = params["embed"][tokens].astype(params["embed"].dtype)
    x = ctx.shard_act(x)

    if cfg.ssm_kind == "xlstm":
        new_states = []
        for kind, p, st in zip(xlstm_block_kinds(cfg), params["blocks"],
                               state["blocks"]):
            fn = xlstm_mod.mlstm if kind == "mlstm" else xlstm_mod.slstm
            x, new_st = fn(ctx, p, cfg, x, valid, st)
            new_states.append(new_st)
        if last_only:
            x = x[:, -1:]
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        if return_hidden:
            return x, {"blocks": new_states}
        logits = col_dense(ctx, params["lm_head"], x, cfg.vocab, valid)
        return logits.astype(jnp.float32), {"blocks": new_states}

    # [] (scalar, shared) or [B] (per-row slot positions); same all layers
    pos = state["kv"]["len"][0]

    def body(x, inp):
        p, cache, ms = inp
        y, new_cache, new_ms = _layer_fwd(cfg, ctx, p, x, valid, cache, ms,
                                          pos, tokens.shape[1], kv_chunk)
        return y, (new_cache, new_ms)

    ms = state.get("mamba")
    if ms is None:
        x, (new_kv, _) = jax.lax.scan(
            lambda x, inp: body(x, (inp[0], inp[1], None)),
            x, (params["layers"], state["kv"]))
        new_state = {"kv": new_kv}
    else:
        x, (new_kv, new_ms) = jax.lax.scan(
            body, x, (params["layers"], state["kv"], ms))
        new_state = {"kv": new_kv, "mamba": new_ms}

    if last_only:
        x = x[:, -1:]
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if return_hidden:
        return x, new_state
    logits = col_dense(ctx, params["lm_head"], x, cfg.vocab, valid)
    return logits.astype(jnp.float32), new_state
