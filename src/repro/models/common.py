"""Shared model machinery: TP context, coded/plain dense, norms, RoPE, init.

Models are pure functions over param pytrees (no flax). Tensor-parallel and
CDC behaviour is threaded through ``TPCtx``:

  mode="plain":  column-parallel GEMMs are ordinary matmuls; GSPMD shards
                 them via the constraints in dist.sharding (megatron-style,
                 uncoded baseline).
  mode="coded":  column-parallel GEMMs run through core.coded_matmul — the
                 paper's output-splitting with parity shards and fused
                 recovery; the merge (gather) happens at every coded GEMM
                 boundary exactly as the paper's distribution does.

Row-parallel GEMMs (attention Wo, FFN W2) are never coded (paper Table 1).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.coded_layer import (CodedDenseSpec, coded_matmul,
                                    make_parity_weights)
from repro.core.coding import CodeSpec

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TPCtx:
    """Static tensor-parallel + CDC context for a model invocation."""

    tp: int = 1                    # T: logical shards of every coded GEMM
    mode: str = "plain"            # plain | coded
    code_r: int = 2
    code_layout: str = "folded"
    mesh: Any = None               # jax Mesh for sharding constraints (opt.)
    axis: str = "model"            # TP axis name
    fsdp: str | None = "data"      # FSDP axis name (weights)
    seq_axis: str | None = None    # SP: shard sequence dim of activations
    moe_capacity: float = 1.25     # MoE capacity factor (<= 0: no dropping)
    fused_body: bool = False       # route coded GEMMs through the fused
    #                                Pallas kernel (shard GEMMs + Eq. 12
    #                                decode + merge in-register). Only valid
    #                                in the <=1-erasure regime — the
    #                                executor host-gates the mask before
    #                                tracing with a fused_body ctx.

    @property
    def coded(self) -> bool:
        return self.mode == "coded" and self.tp > 1

    @property
    def spec(self) -> CodedDenseSpec | None:
        if not self.coded:
            return None
        return CodedDenseSpec(CodeSpec(self.tp, self.code_r),
                              layout=self.code_layout)

    def pad_dim(self, m: int) -> int:
        """Column dims of coded GEMMs must split into T x T slices. The same
        padding is applied in plain mode so param shapes (and checkpoints)
        are identical across modes."""
        q = self.tp * self.tp
        return ((m + q - 1) // q) * q

    def shard(self, x: jax.Array, *spec) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def shard_act(self, x: jax.Array, col: bool = False) -> jax.Array:
        """[B, S, D]-style activation constraint: batch over fsdp(+pod),
        optionally last dim over the TP axis."""
        if self.mesh is None:
            return x
        batch_axes = tuple(a for a in ("pod", self.fsdp)
                           if a and a in self.mesh.axis_names)
        batch = batch_axes if batch_axes else None
        spec = [batch] + [None] * (x.ndim - 1)
        if col:
            spec[-1] = self.axis
        return self.shard(x, *spec)


# ---------------------------------------------------------------- dense ----

def linear_init(key, k: int, m: int, ctx: TPCtx, dtype,
                scale: float | None = None, coded: bool = True) -> Params:
    """A (possibly coded) linear layer's params. Stores the padded weight;
    callers slice outputs back to the logical dim."""
    m_pad = ctx.pad_dim(m) if coded else m
    scale = scale if scale is not None else 1.0 / math.sqrt(k)
    w = (jax.random.normal(key, (k, m_pad), jnp.float32) * scale)
    w = w.at[:, m:].set(0.0) if m_pad != m else w
    p: Params = {"w": w.astype(dtype)}
    if coded and ctx.coded:
        p["cdc"] = make_parity_weights(p["w"], ctx.spec)
    return p


def col_dense(ctx: TPCtx, p: Params, x: jax.Array, out_dim: int,
              valid: jax.Array | None = None) -> jax.Array:
    """Column-parallel (output-split) GEMM — CODEABLE (paper Table 1)."""
    w = p["w"]
    if ctx.coded and "cdc" in p:
        y = coded_matmul(x, w, p["cdc"], ctx.spec, valid,
                         use_fused=ctx.fused_body)
        y = ctx.shard_act(y)          # merged output, replicated over TP
    else:
        y = x @ w
        y = ctx.shard_act(y, col=True)
    return y[..., :out_dim] if y.shape[-1] != out_dim else y


def row_dense(ctx: TPCtx, p: Params, x: jax.Array) -> jax.Array:
    """Row-parallel (input-split) GEMM — NOT codeable (paper Eq. 13-14);
    GSPMD reduces the partial sums with a psum/reduce-scatter."""
    y = x @ p["w"]
    return ctx.shard_act(y)


def encode_tree(params: Params, ctx: TPCtx) -> Params:
    """(Re)compute every parity leaf from its base weight — the paper's
    OFFLINE encode pass ('CDC weights are created offline and loaded to the
    storage', §6). Run after init, load, or any weight update."""
    if not ctx.coded:
        return params

    def walk(node):
        if isinstance(node, dict):
            if "w" in node and "cdc" in node:
                node = dict(node)
                node["cdc"] = make_parity_weights(node["w"], ctx.spec)
                return node
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


# ---------------------------------------------------------------- norms ----

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * p["g"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)
            + p["b"].astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- rope ----

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos,
                           x[..., 2 * half:]], axis=-1)
    return rot.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div[: (d + 1) // 2]))
    return pe.astype(dtype)


def chunked_time_scan(step, init, xs, chunk: int = 64):
    """lax.scan over time with chunk-level activation checkpointing.

    A plain scan over S=4096 steps makes the backward pass save every
    per-step carry (O(S * state) — 80+ GB for mLSTM matrix memory). Scanning
    over S/chunk rematerialized chunks keeps only chunk-boundary carries:
    peak O((S/chunk + chunk) * state).

    xs: pytree with leading time dim S; returns (carry, ys) like lax.scan.
    """
    leaves = jax.tree.leaves(xs)
    S = leaves[0].shape[0]
    if S <= chunk or S % chunk:
        return jax.lax.scan(step, init, xs)
    n = S // chunk

    def inner(carry, xc):
        return jax.lax.scan(step, carry, xc)

    inner = jax.checkpoint(inner)

    def outer(carry, xc):
        return inner(carry, xc)

    xs_c = jax.tree.map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)
    carry, ys_c = jax.lax.scan(outer, init, xs_c)
    ys = jax.tree.map(
        lambda a: a.reshape((a.shape[0] * a.shape[1],) + a.shape[2:]), ys_c)
    return carry, ys


def activation(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(name)
