"""Uniform model API over all assigned architecture families.

Model(cfg, ctx) exposes:
  init(key, dtype)                      -> params
  forward(params, batch, valid=None)    -> logits       (train / prefill)
  init_decode(params, batch_inputs, b, max_len) -> state
  decode(params, state, tokens, valid=None) -> (logits, state)
  input_spec(shape, dtype)              -> ShapeDtypeStruct batch stand-ins

``batch`` is a dict: {"tokens": [B, S]} for LMs; whisper adds
{"frames": [B, enc_seq, D]} (frontend stub); chameleon's VQ image tokens are
ordinary ids in the 65536 vocab (tokenizer stub).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.common import TPCtx, encode_tree

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    ctx: TPCtx

    # ---------------------------------------------------------- params ----
    def init(self, key, dtype=jnp.float32) -> Params:
        if self.cfg.is_encdec:
            return encdec.init_params(self.cfg, key, self.ctx, dtype)
        return transformer.init_params(self.cfg, key, self.ctx, dtype)

    def encode_offline(self, params: Params) -> Params:
        """The paper's offline CDC weight encode (rerun after weight load)."""
        return encode_tree(params, self.ctx)

    # --------------------------------------------------------- forward ----
    def forward(self, params: Params, batch: dict, valid=None, *,
                remat: str = "full", q_chunk: int = 512,
                kv_chunk: int = 1024) -> jax.Array:
        if self.cfg.is_encdec:
            return encdec.forward(self.cfg, params, self.ctx,
                                  batch["tokens"], batch["frames"], valid,
                                  remat=remat, q_chunk=q_chunk,
                                  kv_chunk=kv_chunk)
        return transformer.forward(self.cfg, params, self.ctx,
                                   batch["tokens"], valid, remat=remat,
                                   q_chunk=q_chunk, kv_chunk=kv_chunk)

    # ---------------------------------------------------------- decode ----
    def init_decode(self, params: Params, batch: dict, b: int, max_len: int,
                    dtype=jnp.bfloat16, valid=None,
                    per_row: bool = False) -> Params:
        if self.cfg.is_encdec:
            return encdec.init_decode_state(self.cfg, self.ctx, params,
                                            batch["frames"], b, max_len,
                                            dtype, valid, per_row=per_row)
        return transformer.init_decode_state(self.cfg, self.ctx, b, max_len,
                                             dtype, per_row=per_row)

    def decode(self, params: Params, state: Params, tokens: jax.Array,
               valid=None, *, kv_chunk: int = 1024, last_only: bool = False,
               return_hidden: bool = False):
        if self.cfg.is_encdec:
            return encdec.decode_step(self.cfg, params, self.ctx, state,
                                      tokens, valid, kv_chunk=kv_chunk,
                                      last_only=last_only,
                                      return_hidden=return_hidden)
        return transformer.decode_step(self.cfg, params, self.ctx, state,
                                       tokens, valid, kv_chunk=kv_chunk,
                                       last_only=last_only,
                                       return_hidden=return_hidden)

    # ----------------------------------------------------------- specs ----
    def input_spec(self, batch: int, seq: int, dtype=jnp.bfloat16) -> dict:
        spec = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
        if self.cfg.is_encdec:
            spec["frames"] = jax.ShapeDtypeStruct(
                (batch, self.cfg.enc_seq, self.cfg.d_model), dtype)
        return spec

    def dummy_batch(self, key, batch: int, seq: int, dtype=jnp.float32
                    ) -> dict:
        kt, kf = jax.random.split(key)
        out = {"tokens": jax.random.randint(kt, (batch, seq), 0,
                                            self.cfg.vocab, jnp.int32)}
        if self.cfg.is_encdec:
            out["frames"] = jax.random.normal(
                kf, (batch, self.cfg.enc_seq, self.cfg.d_model), dtype)
        return out


def build(cfg, ctx: TPCtx | None = None) -> Model:
    return Model(cfg, ctx or TPCtx())
