from repro.models.common import TPCtx
from repro.models.zoo import Model, build
