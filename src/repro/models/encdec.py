"""Whisper-style encoder-decoder backbone.

The audio/conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, enc_seq, d_model] (the two-conv
mel frontend would live in front of the encoder on real deployments; its
cost is negligible next to the 24+24 transformer layers).

Encoder: bidirectional attention blocks (LayerNorm + GELU FFN, scanned).
Decoder: causal self-attention (+ KV cache) and cross-attention over the
encoder output (cross-KV computed once per request and cached). All QKV /
FFN-up projections are column-parallel => coded under CDC like every other
arch; whisper has no decode-free path — decode shapes exercise the decoder.

``init_decode_state(per_row=True)`` emits the slot-batched layout the
runtime executor stacks: per-row self-attention cache positions plus a
per-row cross-KV "extras bank" ([L, B, Se, ...] K/V with [L, B, Se]
positions), so enc-dec slots ride the one-dispatch-per-round path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models.common import (Params, TPCtx, col_dense, layernorm,
                                 layernorm_init, linear_init, sinusoidal_pos)


def _enc_layer_init(key, cfg, ctx, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln1": layernorm_init(cfg.d_model),
        "attn": attn_mod.attn_init(ks[0], cfg, ctx, dtype),
        "ln2": layernorm_init(cfg.d_model),
        "ffn": ffn_mod.ffn_init(ks[1], cfg, ctx, dtype),
    }


def _dec_layer_init(key, cfg, ctx, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "ln1": layernorm_init(cfg.d_model),
        "self": attn_mod.attn_init(ks[0], cfg, ctx, dtype),
        "ln_x": layernorm_init(cfg.d_model),
        "cross": attn_mod.attn_init(ks[1], cfg, ctx, dtype),
        "ln2": layernorm_init(cfg.d_model),
        "ffn": ffn_mod.ffn_init(ks[2], cfg, ctx, dtype),
    }


def init_params(cfg, key, ctx: TPCtx, dtype=jnp.float32) -> Params:
    k_emb, k_head, k_enc, k_dec = jax.random.split(key, 4)
    d = cfg.d_model
    vocab_pad = ctx.pad_dim(cfg.vocab)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": (jax.random.normal(k_emb, (vocab_pad, d), jnp.float32)
                  * 0.02).astype(dtype),
        "enc_layers": jax.vmap(
            lambda k: _enc_layer_init(k, cfg, ctx, dtype))(enc_keys),
        "enc_ln_f": layernorm_init(d),
        "dec_layers": jax.vmap(
            lambda k: _dec_layer_init(k, cfg, ctx, dtype))(dec_keys),
        "dec_ln_f": layernorm_init(d),
        "lm_head": linear_init(k_head, d, cfg.vocab, ctx, dtype,
                               scale=1.0 / d ** 0.5),
    }


def encode(cfg, params: Params, ctx: TPCtx, frames: jax.Array,
           valid=None, *, remat: str = "full") -> jax.Array:
    """frames: [B, Se, D] precomputed embeddings (frontend stub)."""
    x = frames + sinusoidal_pos(frames.shape[1], cfg.d_model,
                                frames.dtype)[None]
    x = ctx.shard_act(x)

    def body(x, p):
        a, _ = attn_mod.attention(ctx, p["attn"], cfg,
                                  layernorm(p["ln1"], x, cfg.norm_eps),
                                  valid=valid, kind="bidir")
        x = x + a
        x = x + ffn_mod.ffn(ctx, p["ffn"], cfg,
                            layernorm(p["ln2"], x, cfg.norm_eps), valid)
        return x, None

    wrapped = jax.checkpoint(body) if remat != "none" else body
    x, _ = jax.lax.scan(wrapped, x, params["enc_layers"])
    return layernorm(params["enc_ln_f"], x, cfg.norm_eps)


def _dec_layer(cfg, ctx, p, x, valid, cache, xkv, pos, q_chunk, kv_chunk):
    a, new_cache = attn_mod.attention(
        ctx, p["self"], cfg, layernorm(p["ln1"], x, cfg.norm_eps),
        valid=valid, cache=cache, pos_offset=pos, kind="causal",
        q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = x + a
    c, _ = attn_mod.attention(
        ctx, p["cross"], cfg, layernorm(p["ln_x"], x, cfg.norm_eps),
        valid=valid, kind="bidir", kv_override=xkv,
        q_chunk=q_chunk, kv_chunk=kv_chunk)
    x = x + c
    x = x + ffn_mod.ffn(ctx, p["ffn"], cfg,
                        layernorm(p["ln2"], x, cfg.norm_eps), valid)
    return x, new_cache


def forward(cfg, params: Params, ctx: TPCtx, tokens: jax.Array,
            frames: jax.Array, valid=None, *, remat: str = "full",
            q_chunk: int = 512, kv_chunk: int = 1024) -> jax.Array:
    """Teacher-forced train/prefill. tokens: [B, S]; frames: [B, Se, D]."""
    enc = encode(cfg, params, ctx, frames, valid, remat=remat)
    x = params["embed"][tokens].astype(params["embed"].dtype)
    x = x + sinusoidal_pos(tokens.shape[1], cfg.d_model, x.dtype)[None]
    x = ctx.shard_act(x)

    def body(x, p):
        xkv = attn_mod.cross_kv(ctx, p["cross"], cfg, enc, valid)
        y, _ = _dec_layer(cfg, ctx, p, x, valid, None, xkv, 0,
                          q_chunk, kv_chunk)
        return y, None

    wrapped = jax.checkpoint(body) if remat != "none" else body
    x, _ = jax.lax.scan(wrapped, x, params["dec_layers"])
    x = layernorm(params["dec_ln_f"], x, cfg.norm_eps)
    logits = col_dense(ctx, params["lm_head"], x, cfg.vocab, valid)
    return logits.astype(jnp.float32)


def init_decode_state(cfg, ctx: TPCtx, params: Params, frames: jax.Array,
                      batch: int, max_len: int, dtype=jnp.bfloat16,
                      valid=None, per_row: bool = False) -> Params:
    """Runs the encoder once, precomputes per-layer cross-KV, allocates the
    self-attention cache.

    ``per_row=True`` builds the slot-batched layout: the self-attention
    cache carries per-row lengths/positions and the cross-KV positions are
    per-row too ([B, Se] per layer), so every decode-state leaf — the
    encoder-derived cross-attention bank included — is [L, B, ...] and a
    slot admission can overwrite one row of the stacked executor state."""
    b = frames.shape[0]
    enc = encode(cfg, params, ctx, frames, valid)

    def one_xkv(p):
        k, v, kp = attn_mod.cross_kv(ctx, p["cross"], cfg, enc, valid)
        if per_row:
            kp = jnp.broadcast_to(kp, (b, kp.shape[-1]))
        return {"k": k.astype(dtype), "v": v.astype(dtype), "pos": kp}

    xkv = jax.vmap(one_xkv)(params["dec_layers"])
    kv = jax.vmap(lambda _: attn_mod.init_cache(
        cfg, batch, max_len, dtype, tp=ctx.tp,
        per_row=per_row))(jnp.arange(cfg.n_layers))
    return {"kv": kv, "xkv": xkv}


def decode_step(cfg, params: Params, ctx: TPCtx, state: Params,
                tokens: jax.Array, valid=None, *, kv_chunk: int = 1024,
                last_only: bool = False, return_hidden: bool = False
                ) -> tuple[jax.Array, Params]:
    # [] (scalar, shared) or [B] (per-row slot positions); same all layers
    pos = state["kv"]["len"][0]
    x = params["embed"][tokens].astype(params["embed"].dtype)
    s = tokens.shape[1]
    # position table sized to the query; beyond-table positions wrap (the
    # assigned 32k shapes exceed whisper's native 448-token decoder — the
    # wrap keeps the lowering well-defined)
    tab = max(8192, s)
    pe = sinusoidal_pos(tab, cfg.d_model, x.dtype)
    if jnp.ndim(pos):
        x = x + pe[(pos[:, None] + jnp.arange(s)) % tab]
    else:
        x = x + jax.lax.dynamic_slice_in_dim(pe, pos % tab, s, 0)[None]
    x = ctx.shard_act(x)

    def body(x, inp):
        p, cache, xkv = inp
        y, new_cache = _dec_layer(cfg, ctx, p, x, valid, cache,
                                  (xkv["k"], xkv["v"], xkv["pos"]), pos,
                                  s, kv_chunk)
        return y, new_cache

    x, new_kv = jax.lax.scan(body, x,
                             (params["dec_layers"], state["kv"],
                              state["xkv"]))
    if last_only:
        x = x[:, -1:]
    x = layernorm(params["dec_ln_f"], x, cfg.norm_eps)
    new_state = {"kv": new_kv, "xkv": state["xkv"]}
    if return_hidden:
        return x, new_state
    logits = col_dense(ctx, params["lm_head"], x, cfg.vocab, valid)
    return logits.astype(jnp.float32), new_state
