"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, strictly recurrent) — arXiv:2405.04517, simplified but faithful
exponential-gating + stabilizer math.

CDC applies to the up/qkv projections (column-parallel, output split); the
recurrences are per-head shard-local ops between coded GEMM boundaries.
State is O(1) in sequence length => long_500k decode is runnable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (Params, TPCtx, chunked_time_scan,
                                 col_dense, layernorm, layernorm_init,
                                 linear_init, row_dense)


# ------------------------------------------------------------- mLSTM -------

def mlstm_init(key, cfg, ctx: TPCtx, dtype) -> Params:
    d = cfg.d_model
    du = 2 * d  # up-projection factor 2
    nh = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": layernorm_init(d, jnp.float32),
        "up": linear_init(ks[0], d, 2 * du, ctx, dtype),  # x_m and gate z
        "wq": linear_init(ks[1], du, du, ctx, dtype),
        "wk": linear_init(ks[2], du, du, ctx, dtype),
        "wv": linear_init(ks[3], du, du, ctx, dtype),
        "wif": (jax.random.normal(ks[4], (du, 2 * nh), jnp.float32)
                / du ** 0.5).astype(dtype),
        "b_if": jnp.zeros((2 * nh,), jnp.float32),
        "down": linear_init(ks[5], du, d, ctx, dtype,
                            scale=1.0 / du ** 0.5, coded=False),
    }


def _mlstm_chunkwise(q, k, v, i_raw, f_log, c0, n0, m0, chunk: int = 128):
    """Chunkwise-parallel mLSTM (xLSTM appendix / GLA-style).

    §Perf hillclimb 1: the sequential scan reads+writes the [B,nh,dh,dh]
    matrix memory EVERY timestep — ~10 TB of HBM traffic per train step for
    xlstm-125m (measured: memory term 82 s). The recurrence is linear in C
    between gate applications, so a W-token chunk folds into:
      intra-chunk: causal attention-like matmuls with decay weights
                   A[t,tau] = exp(g_tau - M_t) * (q_t . k_tau)
      inter-chunk: C carried ONCE per chunk boundary.
    Stabilized with M_t = max(m0, cummax g), all exponents <= 0.

    q,k,v: [B, W*, nh, dh] per chunk slices; gates [B, W*, nh].
    Returns (h [B, S, nh, dh], (C, n, m) final).
    """
    b, s, nh, dh = q.shape
    w = min(chunk, s)
    if s % w:
        pad = w - s % w
        zq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(a, zq) for a in (q, k, v))
        # padded steps: i = -inf (no write), f = 0 (keep state)
        i_raw = jnp.pad(i_raw, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        f_log = jnp.pad(f_log, ((0, 0), (0, pad), (0, 0)))
    n_chunks = q.shape[1] // w

    def to_chunks(a):
        return jnp.moveaxis(
            a.reshape((b, n_chunks, w) + a.shape[2:]), 1, 0)

    xs = tuple(map(to_chunks, (q, k, v, i_raw, f_log)))

    def chunk_step(carry, inp):
        c, n, m = carry  # [B,nh,dh,dh], [B,nh,dh], [B,nh]
        qi, ki, vi, ii, fi = inp  # [B,w,nh,dh] x3, [B,w,nh] x2
        F = jnp.cumsum(fi, axis=1)                     # [B,w,nh]
        g = ii - F
        M = jnp.maximum(jax.lax.cummax(g, axis=1), m[:, None])
        scores = jnp.einsum("bthd,bchd->bhtc", qi, ki,
                            preferred_element_type=jnp.float32)
        decay = jnp.exp(jnp.moveaxis(g, 1, 2)[:, :, None, :]
                        - jnp.moveaxis(M, 1, 2)[:, :, :, None])
        causal = jnp.tril(jnp.ones((w, w), bool))
        A = jnp.where(causal[None, None], scores * decay, 0.0)
        inter = jnp.exp(m[:, None] - M)                # [B,w,nh]
        num = jnp.einsum("bhij,bthj->bthi", c, qi) * inter[..., None] \
            + jnp.einsum("bhtc,bchd->bthd", A, vi.astype(jnp.float32))
        den = jnp.einsum("bhj,bthj->bth", n, qi) * inter \
            + A.sum(-1).transpose(0, 2, 1)
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # chunk-end state
        wN = jnp.exp(g - M[:, -1:, :])                 # [B,w,nh]
        keep = jnp.exp(m - M[:, -1])                   # [B,nh]
        c_new = c * keep[..., None, None] \
            + jnp.einsum("bchd,bche,bch->bhde", vi.astype(jnp.float32),
                         ki.astype(jnp.float32), wN)
        n_new = n * keep[..., None] \
            + jnp.einsum("bche,bch->bhe", ki.astype(jnp.float32), wN)
        # m_W = F_W + M_W where M_W = max(m0, max_tau g_tau)
        m_new = F[:, -1] + jnp.maximum(jnp.max(g, axis=1), m)
        return (c_new, n_new, m_new), h

    (cT, nT, mT), hs = jax.lax.scan(chunk_step, (c0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(b, n_chunks * w, nh, dh)[:, :s]
    return h, (cT, nT, mT)


def mlstm(ctx: TPCtx, p: Params, cfg, x: jax.Array, valid=None,
          state: Params | None = None):
    """x: [B, S, D] -> ([B, S, D], state). Matrix memory C: [B, nh, dh, dh]."""
    b, s, d = x.shape
    du = 2 * d
    nh = cfg.n_heads
    dh = du // nh
    xn = layernorm(p["norm"], x, cfg.norm_eps)
    up = col_dense(ctx, p["up"], xn, 2 * du, valid)
    xm, z = up[..., :du], up[..., du:]

    q = col_dense(ctx, p["wq"], xm, du, valid).reshape(b, s, nh, dh)
    k = col_dense(ctx, p["wk"], xm, du, valid).reshape(b, s, nh, dh) \
        / dh ** 0.5
    v = col_dense(ctx, p["wv"], xm, du, valid).reshape(b, s, nh, dh)

    gates = (xm @ p["wif"]).astype(jnp.float32) + p["b_if"]  # [B, S, 2nh]
    i_raw, f_raw = gates[..., :nh], gates[..., nh:]
    f_log = -jax.nn.softplus(-f_raw)  # log sigmoid(f)

    if state is None:
        c0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
        m0 = jnp.full((b, nh), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state["c"], state["n"], state["m"]

    def step(carry, inp):
        c, n, m = carry
        qi, ki, vi, ii, fi = inp  # [B,nh,dh] x3, [B,nh] x2
        m_new = jnp.maximum(fi + m, ii)
        i_g = jnp.exp(ii - m_new)[..., None]
        f_g = jnp.exp(fi + m - m_new)[..., None]
        c = f_g[..., None] * c + i_g[..., None] * \
            (vi[..., :, None] * ki[..., None, :])  # [B,nh,dh,dh]
        n = f_g * n + i_g * ki
        num = jnp.einsum("bhij,bhj->bhi", c, qi)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qi)), 1.0)
        h = num / den[..., None]
        return (c, n, m_new), h

    if s > 1:  # chunkwise-parallel form (matmuls; §Perf hillclimb 1)
        h4, (cT, nT, mT) = _mlstm_chunkwise(
            q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), i_raw, f_log, c0, n0, m0)
        h = h4.reshape(b, s, du).astype(x.dtype)
    else:  # decode: one sequential step
        xs = (jnp.moveaxis(q.astype(jnp.float32), 1, 0),
              jnp.moveaxis(k.astype(jnp.float32), 1, 0),
              jnp.moveaxis(v.astype(jnp.float32), 1, 0),
              jnp.moveaxis(i_raw, 1, 0), jnp.moveaxis(f_log, 1, 0))
        (cT, nT, mT), hs = chunked_time_scan(step, (c0, n0, m0), xs)
        h = jnp.moveaxis(hs, 0, 1).reshape(b, s, du).astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = row_dense(ctx, p["down"], h)
    return x + out, {"c": cT, "n": nT, "m": mT}


def init_mlstm_state(cfg, batch: int) -> Params:
    du = 2 * cfg.d_model
    nh = cfg.n_heads
    dh = du // nh
    return {"c": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


# ------------------------------------------------------------- sLSTM -------

def slstm_init(key, cfg, ctx: TPCtx, dtype) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 4)
    return {
        "norm": layernorm_init(d, jnp.float32),
        "wx": linear_init(ks[0], d, 4 * d, ctx, dtype),   # z, i, f, o
        "r": (jax.random.normal(ks[1], (nh, dh, 4 * dh), jnp.float32)
              / dh ** 0.5).astype(dtype),                 # block-diag recur.
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "down": linear_init(ks[2], d, d, ctx, dtype,
                            scale=1.0 / d ** 0.5, coded=False),
    }


def slstm(ctx: TPCtx, p: Params, cfg, x: jax.Array, valid=None,
          state: Params | None = None):
    """Strictly recurrent scalar LSTM with exponential gating."""
    b, s, d = x.shape
    nh = cfg.n_heads
    dh = d // nh
    xn = layernorm(p["norm"], x, cfg.norm_eps)
    wx = col_dense(ctx, p["wx"], xn, 4 * d, valid)  # [B, S, 4D]

    if state is None:
        h0 = jnp.zeros((b, nh, dh), jnp.float32)
        c0 = jnp.zeros((b, nh, dh), jnp.float32)
        n0 = jnp.ones((b, nh, dh), jnp.float32)
        m0 = jnp.zeros((b, nh, dh), jnp.float32)
    else:
        h0, c0, n0, m0 = (state["h"], state["c"], state["n"], state["m"])

    r = p["r"].astype(jnp.float32)
    bias = p["bias"]

    def step(carry, wxt):
        h, c, n, m = carry  # [B, nh, dh]
        rec = jnp.einsum("bhi,hij->bhj", h, r)  # [B, nh, 4dh]
        pre = wxt.astype(jnp.float32).reshape(b, nh, 4 * dh) + rec \
            + bias.reshape(nh, 4 * dh)[None]
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        f_log = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(f_log + m, it)
        i_g = jnp.exp(it - m_new)
        f_g = jnp.exp(f_log + m - m_new)
        c = f_g * c + i_g * zt
        n = f_g * n + i_g
        h = ot * c / jnp.maximum(n, 1.0)
        return (h, c, n, m_new), h

    # reshape wx so each head's 4 gates are contiguous: [B,S,nh,4dh]
    wxs = wx.reshape(b, s, 4, nh, dh)
    wxs = jnp.moveaxis(wxs, 2, 3).reshape(b, s, nh, 4 * dh)
    (hT, cT, nT, mT), hs = chunked_time_scan(
        step, (h0, c0, n0, m0), jnp.moveaxis(wxs, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
    out = row_dense(ctx, p["down"], h)
    return x + out, {"h": hT, "c": cT, "n": nT, "m": mT}


def init_slstm_state(cfg, batch: int) -> Params:
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    z = jnp.zeros((batch, nh, dh), jnp.float32)
    return {"h": z, "c": z, "n": jnp.ones_like(z), "m": z}
