"""FFN blocks: dense (SwiGLU / GELU) and Mixture-of-Experts.

Dense: W1/W3 are column-parallel => CODED in coded mode; W2 row-parallel,
never coded (paper Table 1).

MoE: routed experts are sharded over the `model` axis (expert parallelism);
CDC is NOT applied across experts — routing is input-dependent, so no shared
factor exists between expert outputs (the same algebra that rules out input
splitting in paper Eq. 13-14; DESIGN.md §3). Shared experts are an ordinary
dense FFN and ARE coded. Dispatch is sort-based with a capacity bound
(MaxText-style "dropping"), which lowers to sort+scatter HLO and shards to
all-to-all-ish collectives under EP — no [tokens, E, capacity] one-hot blowup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (Params, TPCtx, activation, col_dense,
                                 linear_init, row_dense)


def ffn_init(key, cfg, ctx: TPCtx, dtype, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w1": linear_init(ks[0], d, f, ctx, dtype),
        "w2": linear_init(ks[1], f, d, ctx, dtype,
                          scale=1.0 / f ** 0.5, coded=False),
    }
    if cfg.act == "silu":  # gated
        p["w3"] = linear_init(ks[2], d, f, ctx, dtype)
    return p


def ffn(ctx: TPCtx, p: Params, cfg, x: jax.Array, valid=None,
        d_ff: int | None = None) -> jax.Array:
    f = d_ff if d_ff is not None else cfg.d_ff
    h = col_dense(ctx, p["w1"], x, f, valid)
    h = activation(cfg.act, h)
    if "w3" in p:
        h = h * col_dense(ctx, p["w3"], x, f, valid)
    return row_dense(ctx, p["w2"], h)


# ------------------------------------------------------------------ MoE ----

def _pad_experts(n_experts: int, tp: int) -> int:
    """EP requires n_experts % tp == 0 (qwen2's 60 -> 64; extra experts are
    real parameters but the router never selects them beyond noise)."""
    return ((n_experts + tp - 1) // tp) * tp


def moe_init(key, cfg, ctx: TPCtx, dtype) -> Params:
    d, fe = cfg.d_model, cfg.d_ff_expert
    e = _pad_experts(cfg.n_experts, ctx.tp)
    ks = jax.random.split(key, 5)
    scale = 1.0 / d ** 0.5
    p: Params = {
        "router": {"w": (jax.random.normal(ks[0], (d, e), jnp.float32)
                         * scale).astype(dtype)},
        # experts stacked on a leading E axis (sharded over `model` = EP)
        "we1": (jax.random.normal(ks[1], (e, d, fe), jnp.float32)
                * scale).astype(dtype),
        "we3": (jax.random.normal(ks[2], (e, d, fe), jnp.float32)
                * scale).astype(dtype),
        "we2": (jax.random.normal(ks[3], (e, fe, d), jnp.float32)
                * (1.0 / fe ** 0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = ffn_init(ks[4], cfg, ctx, dtype,
                               d_ff=cfg.n_shared_experts * fe)
    return p


def _route(ctx: TPCtx, router_w, xf, k: int, e: int):
    """Shared routing math: top-k gates + globally-sorted dispatch order.

    Deterministic and identical on every rank (inputs are model-replicated),
    so the sharded path needs NO routing communication at all.
    """
    n = xf.shape[0]
    logits = (xf @ router_w).astype(jnp.float32)  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # [N, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    m = n * k
    flat_e = eidx.reshape(m)
    flat_g = gates.reshape(m)
    flat_t = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e)
    se, sg, st = flat_e[order], flat_g[order], flat_t[order]
    grp_start = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(m) - grp_start
    if ctx.moe_capacity and ctx.moe_capacity > 0:
        cap = int(max(1, ctx.moe_capacity * m / e))
    else:
        cap = m  # no dropping (exactness mode; memory O(E*M))
    keep = pos < cap
    return se, sg, st, pos, keep, cap


def _expert_ffn(buf, we1, we3, we2):
    h = jnp.einsum("ecd,edf->ecf", buf, we1)
    h = activation("silu", h)
    h = h * jnp.einsum("ecd,edf->ecf", buf, we3)
    return jnp.einsum("ecf,efd->ecd", h, we2)  # [E, cap, D]


def moe(ctx: TPCtx, p: Params, cfg, x: jax.Array, valid=None) -> jax.Array:
    """Top-k routed MoE with sort-based capacity dispatch.

    x: [B, S, D] -> [B, S, D].

    Sharded path (§Perf hillclimb 2): the naive GSPMD lowering of the
    scatter-add dispatch moved ~150 TB/step of all-reduce on qwen3-moe
    train_4k (the [E, cap, D] buffers and [N, D] combine cross the token <->
    expert sharding boundary per layer). Because activations are REPLICATED
    over `model`, each rank can dispatch tokens to its OWN expert slab with
    zero communication; the only wire cost is one bf16 psum of [N, D] for
    the combine — the same bytes as a megatron FFN all-reduce.
    """
    b, s, d = x.shape
    k = cfg.top_k
    e = p["we1"].shape[0]
    n = b * s
    tp = (ctx.mesh.shape[ctx.axis]
          if ctx.mesh is not None and ctx.axis in ctx.mesh.axis_names else 1)

    if tp > 1 and e % tp == 0:
        y = _moe_sharded(ctx, p, cfg, x.reshape(n, d), e, k, tp)
    else:
        y = _moe_local(ctx, p, x.reshape(n, d), e, k)
    y = y.reshape(b, s, d)

    if "shared" in p:
        y = y + ffn(ctx, p["shared"], cfg, x, valid,
                    d_ff=cfg.n_shared_experts * cfg.d_ff_expert)
    return y


def _moe_local(ctx: TPCtx, p: Params, xf, e: int, k: int):
    se, sg, st, pos, keep, cap = _route(ctx, p["router"]["w"], xf, k, e)
    d = xf.shape[-1]
    buf = jnp.zeros((e, cap, d), xf.dtype)
    buf = buf.at[se, jnp.minimum(pos, cap - 1)].add(
        jnp.where(keep[:, None], xf[st], 0))
    out = _expert_ffn(buf, p["we1"], p["we3"], p["we2"])
    y = jnp.zeros((xf.shape[0], d), jnp.float32)
    contrib = out[se, jnp.minimum(pos, cap - 1)].astype(jnp.float32)
    y = y.at[st].add(jnp.where(keep[:, None], contrib * sg[:, None], 0))
    return y.astype(xf.dtype)


def _moe_sharded(ctx: TPCtx, p: Params, cfg, xf, e: int, k: int, tp: int):
    """Full-manual shard_map: tokens stay on their batch shard, experts on
    their EP rank; routing math is local (N_local tokens), dispatch is
    local, the combine is ONE psum over the EP axis."""
    from jax.sharding import PartitionSpec as P

    e_local = e // tp
    axis = ctx.axis
    mesh = ctx.mesh
    batch_axes = tuple(a for a in ("pod", ctx.fsdp)
                       if a and a in mesh.axis_names)
    n = xf.shape[0]
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    if n % n_batch or not batch_axes:
        batch_axes = ()  # tiny batches: replicate tokens over batch axes

    def f(xf, router_w, we1, we3, we2):
        rank = jax.lax.axis_index(axis)
        se, sg, st, pos, keep, cap = _route(ctx, router_w, xf, k, e)
        d = xf.shape[-1]
        e0 = rank * e_local
        mine = (se >= e0) & (se < e0 + e_local) & keep
        se_l = jnp.clip(se - e0, 0, e_local - 1)
        # local dispatch: tokens already resident, experts already resident
        buf = jnp.zeros((e_local, cap, d), xf.dtype)
        buf = buf.at[se_l, jnp.minimum(pos, cap - 1)].add(
            jnp.where(mine[:, None], xf[st], 0))
        out = _expert_ffn(buf, we1, we3, we2)
        contrib = out[se_l, jnp.minimum(pos, cap - 1)]
        y = jnp.zeros((xf.shape[0], d), xf.dtype)
        y = y.at[st].add(
            jnp.where(mine[:, None],
                      contrib * sg[:, None].astype(contrib.dtype), 0))
        # ONE combine: psum over the EP axis (the only wire cost)
        return jax.lax.psum(y, axis)

    from repro.dist.compat import shard_map

    x_spec = P(batch_axes if batch_axes else None, None)
    fn = shard_map(
        f, mesh,
        (x_spec, P(None, None), P(axis, None, None),
         P(axis, None, None), P(axis, None, None)),
        x_spec)
    return fn(xf, p["router"]["w"], p["we1"], p["we3"], p["we2"])


def moe_aux_loss(p: Params, cfg, x: jax.Array) -> jax.Array:
    """Load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e."""
    b, s, d = x.shape
    logits = (x.reshape(-1, d) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    e = probs.shape[-1]
    _, eidx = jax.lax.top_k(probs, cfg.top_k)
    frac = jnp.mean(jax.nn.one_hot(eidx, e, dtype=jnp.float32), axis=(0, 1))
    imp = probs.mean(0)
    return e * jnp.sum(frac * imp)
