"""Selective-SSM (Mamba) branch — used by hymba's parallel attn+mamba blocks.

TPU adaptation: the recurrence is a per-channel linear scan (VPU work, not
MXU); the heavy GEMMs (in/out projections) are ordinary column/row-parallel
layers, so CDC coding applies to in_proj exactly like any output-split GEMM
(DESIGN.md §3) and the nonlinear recurrence stays shard-local between coded
boundaries. State is O(1) in sequence length => the long_500k decode cell is
runnable for SSM/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (Params, TPCtx, chunked_time_scan,
                                 col_dense, linear_init, row_dense)

CONV_K = 4


def mamba_init(key, cfg, ctx: TPCtx, dtype) -> Params:
    d = cfg.d_model
    di = d  # branch width (parallel to attention in hymba)
    n = cfg.ssm_state
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": linear_init(ks[0], d, 2 * di, ctx, dtype),   # x and gate z
        "conv_w": (jax.random.normal(ks[1], (CONV_K, di), jnp.float32)
                   * 0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wbc": linear_init(ks[2], di, 2 * n, ctx, dtype, coded=False),
        "wdt1": (jax.random.normal(ks[3], (di, dt_rank), jnp.float32)
                 / d ** 0.5).astype(dtype),
        "wdt2": (jax.random.normal(ks[4], (dt_rank, di), jnp.float32)
                 / dt_rank ** 0.5).astype(dtype),
        "dt_bias": jnp.zeros((di,), dtype),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),             # [di, n]
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": linear_init(ks[5], di, d, ctx, dtype,
                                scale=1.0 / di ** 0.5, coded=False),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: [B, S, di]; w: [K, di]; state: [B, K-1, di].

    Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # [B, S+K-1, di]
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_state = xp[:, -(k - 1):]
    return y + b[None, None], new_state


def mamba(ctx: TPCtx, p: Params, cfg, x: jax.Array, valid=None,
          state: Params | None = None):
    """x: [B, S, D] -> ([B, S, D], new_state)."""
    b, s, d = x.shape
    di = d
    n = cfg.ssm_state
    xz = col_dense(ctx, p["in_proj"], x, 2 * di, valid)
    xm, z = xz[..., :di], xz[..., di:]

    conv_state = state["conv"] if state is not None else None
    xm, new_conv = _causal_conv(xm, p["conv_w"], p["conv_b"], conv_state)
    xm = jax.nn.silu(xm)

    bc = xm @ p["wbc"]["w"][:, :2 * n]
    bmat, cmat = bc[..., :n], bc[..., n:]  # [B, S, n]
    dt = jax.nn.softplus(
        (xm @ p["wdt1"]) @ p["wdt2"] + p["dt_bias"][None, None])  # [B, S, di]
    a = -jnp.exp(p["a_log"])  # [di, n]

    decay = jnp.exp(dt.astype(jnp.float32)[..., None] * a[None, None])
    drive = (dt * xm).astype(jnp.float32)[..., None] \
        * bmat.astype(jnp.float32)[..., None, :]  # [B, S, di, n]

    h0 = state["ssm"] if state is not None else jnp.zeros((b, di, n),
                                                          jnp.float32)

    def step(h, inp):
        dec, drv, c = inp  # [B, di, n], [B, di, n], [B, n]
        h = dec * h + drv
        y = jnp.einsum("bdn,bn->bd", h, c)
        return h, y

    hT, ys = chunked_time_scan(
        step, h0,
        (jnp.moveaxis(decay, 1, 0), jnp.moveaxis(drive, 1, 0),
         jnp.moveaxis(cmat.astype(jnp.float32), 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)  # [B, S, di]
    y = (y + xm.astype(jnp.float32) * p["d_skip"][None, None]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = row_dense(ctx, p["out_proj"], y)
    new_state = {"conv": new_conv, "ssm": hT}
    return out, new_state


def init_mamba_state(cfg, batch: int, dtype=jnp.bfloat16) -> Params:
    di, n = cfg.d_model, cfg.ssm_state
    return {"conv": jnp.zeros((batch, CONV_K - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, n), jnp.float32)}
