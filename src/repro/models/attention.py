"""Attention: MHA/GQA, RoPE, full/sliding-window masks, KV cache, flash-style
chunking.

The QKV projections are column-parallel => CODED in coded mode (paper Table 1
"output splitting: Yes"); Wo is row-parallel => never coded ("input
splitting: No").

TP layout: scores/AV shard over QUERY heads (`model` axis). GQA KV heads are
stored at their logical count (cache savings preserved) and broadcast to the
query-head count right before the einsum — a local slice-of-replicated op,
no comm. Head counts that don't divide the TP degree (hymba's 25, xlstm's 4)
are padded with zero-weight heads at init (wo's rows for padded heads are
zero, so they contribute nothing); padding is a run-layout detail, the
logical config is untouched.

Memory: scores for a 32k prefill would be O(S^2); we stream KV chunks with
an online softmax (flash-style) under lax.scan and map over Q chunks.
Decode against a long cache uses a single KV chunk so the cache can stay
sequence-sharded over `model` (flash-decoding style) with GSPMD reducing the
softmax across shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (Params, TPCtx, col_dense, linear_init, rope,
                                 row_dense)

NEG_INF = -1e30


def attn_dims(cfg, tp: int) -> tuple[int, int, int]:
    """(hq_run, hkv_run, group): head counts padded for the TP degree."""
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    hq_run = -(-hq // tp) * tp if tp > 1 else hq
    hkv_run = hkv
    while hq_run % hkv_run:
        hkv_run += 1
    return hq_run, hkv_run, hq_run // hkv_run


def attn_init(key, cfg, ctx: TPCtx, dtype) -> Params:
    d, hd = cfg.d_model, cfg.hd
    hq_run, hkv_run, _ = attn_dims(cfg, ctx.tp)
    ks = jax.random.split(key, 4)
    p = {
        "wq": linear_init(ks[0], d, hq_run * hd, ctx, dtype),
        "wk": linear_init(ks[1], d, hkv_run * hd, ctx, dtype),
        "wv": linear_init(ks[2], d, hkv_run * hd, ctx, dtype),
        "wo": linear_init(ks[3], hq_run * hd, d, ctx, dtype,
                          scale=1.0 / (hq_run * hd) ** 0.5, coded=False),
    }
    # zero the padded query/kv heads so they are semantically absent
    if hq_run != cfg.n_heads:
        wq = p["wq"]["w"].reshape(d, -1)
        p["wq"]["w"] = wq.at[:, cfg.n_heads * hd:hq_run * hd].set(0.0)
        wo = p["wo"]["w"]
        p["wo"]["w"] = wo.at[cfg.n_heads * hd:hq_run * hd, :].set(0.0)
    if hkv_run != cfg.n_kv_heads:
        for nm in ("wk", "wv"):
            w = p[nm]["w"]
            p[nm]["w"] = w.at[:, cfg.n_kv_heads * hd:hkv_run * hd].set(0.0)
    return p


def _mask(q_pos, k_pos, kind: str, window: int):
    """q_pos: [..., Sq], k_pos: [..., Sk] -> bool [..., Sq, Sk] (True =
    attend). The leading dims (if any) are per-row batch dims — slot-batched
    decode gives every cache row its own position vector, so row b's mask is
    built from positions[b].

    kinds: bidir (encoder/cross), causal, swa. Negative k_pos marks an empty
    cache slot and is never attended."""
    dq, dk = q_pos[..., :, None], k_pos[..., None, :]
    valid_slot = dk >= 0
    if kind == "bidir":
        return valid_slot & jnp.ones_like(dq, bool)
    m = (dk <= dq) & valid_slot
    if kind == "swa":
        m &= dk > dq - window
    return m


def _apply_mask(s, msk, n_head_dims: int):
    """Mask scores ``s`` shaped [B, <n_head_dims dims>, Sq, Sk] with ``msk``
    [Sq, Sk] (shared across rows) or [B, Sq, Sk] (per-row positions)."""
    if msk.ndim == 2:
        idx = (None,) * (n_head_dims + 1)
    else:
        idx = (slice(None),) + (None,) * n_head_dims
    return jnp.where(msk[idx], s, NEG_INF)


def _chunk_pos(pos, n: int, chunk: int):
    """[..., S] positions -> [n, ..., chunk] chunks (leading batch dims,
    if any, are preserved per chunk)."""
    pc = pos.reshape(pos.shape[:-1] + (n, chunk))
    return jnp.moveaxis(pc, -2, 0)


def _sdpa_chunked(q, k, v, q_pos, k_pos, *, kind: str, window: int,
                  kv_chunk: int, q_chunk: int, group: int) -> jax.Array:
    """Online-softmax attention over expanded heads.

    q: [B, Sq, H, hd]; k/v: [B, Sk, Hkv, hd] with H = group * Hkv.
    q_pos/k_pos: [Sq]/[Sk] shared positions, or [B, Sq]/[B, Sk] per-row
    (slot-batched decode: every cache row carries its own positions).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    scale = hd ** -0.5
    kv_chunk = min(kv_chunk, sk)
    single_chunk = kv_chunk >= sk
    if group > 1 and not single_chunk:
        # GQA: broadcast KV to query heads (local slice of replicated)
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    q_chunk = min(q_chunk, sq)
    n_kv = -(-sk // kv_chunk)
    pad_k = n_kv * kv_chunk - sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, [(0, 0)] * (k_pos.ndim - 1) + [(0, pad_k)],
                        constant_values=-(10 ** 9))
    hk = k.shape[2]
    kc = k.reshape(b, n_kv, kv_chunk, hk, hd)
    vc = v.reshape(b, n_kv, kv_chunk, hk, hd)
    kpc = _chunk_pos(k_pos, n_kv, kv_chunk)

    def one_q_chunk(args):
        qi, qpi = args  # [B, qc, H, hd], [qc]

        def kv_attend(ki, vi, kpi, carry=None):
            # keep K/V in their storage dtype (bf16 cache must NOT be
            # upcast: an f32 copy of a sequence-sharded cache doubles the
            # gather bytes GSPMD moves); accumulate in f32 via
            # preferred_element_type.
            if carry is None and group > 1:
                # decode fast path, GQA GROUPED: never materialize the
                # expanded [B, C, Hq, hd] KV (8x the cache for deepseek)
                qg = qi.reshape(qi.shape[0], qi.shape[1], -1, group, hd)
                s = jnp.einsum("bqkgd,bckd->bkgqc", qg, ki,
                               preferred_element_type=jnp.float32) * scale
                msk = _mask(qpi, kpi, kind, window)
                s = _apply_mask(s, msk, 2)
                pr = jax.nn.softmax(s, axis=-1)
                o = jnp.einsum("bkgqc,bckd->bqkgd", pr.astype(vi.dtype),
                               vi, preferred_element_type=jnp.float32)
                return o.reshape(qi.shape)
            s = jnp.einsum("bqhd,bchd->bhqc", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qpi, kpi, kind, window)  # [(B,) qc, kc]
            s = _apply_mask(s, msk, 1)
            if carry is None:  # single-chunk fast path (decode)
                p = jax.nn.softmax(s, axis=-1)
                return jnp.einsum("bhqc,bchd->bqhd", p.astype(vi.dtype),
                                  vi, preferred_element_type=jnp.float32)
            acc, m_run, l_run = carry
            m_new = jnp.maximum(m_run, s.max(-1))  # [B, H, qc]
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            pv = jnp.einsum("bhqc,bchd->bhqd", p.astype(vi.dtype), vi,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return acc, m_new, l_new

        if n_kv == 1:
            return kv_attend(kc[:, 0], vc[:, 0], kpc[0])

        qc = qi.shape[1]
        acc0 = jnp.zeros((b, h, qc, hd), jnp.float32)
        m0 = jnp.full((b, h, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)

        def step(carry, inp):
            ki, vi, kpi = inp
            return kv_attend(ki, vi, kpi, carry), None

        (acc, m_run, l_run), _ = jax.lax.scan(
            step, (acc0, m0, l0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), kpc))
        out = acc / jnp.maximum(l_run[..., None], 1e-30)
        return jnp.moveaxis(out, 2, 1)  # [B, qc, H, hd]

    n_q = -(-sq // q_chunk)
    pad_q = n_q * q_chunk - sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, [(0, 0)] * (q_pos.ndim - 1) + [(0, pad_q)])
    if n_q == 1:
        out = one_q_chunk((q, q_pos))
    else:
        qs = jnp.moveaxis(q.reshape(b, n_q, q_chunk, h, hd), 1, 0)
        qps = _chunk_pos(q_pos, n_q, q_chunk)
        outs = jax.lax.map(one_q_chunk, (qs, qps))  # [n_q, B, qc, H, hd]
        out = jnp.moveaxis(outs, 0, 1).reshape(b, n_q * q_chunk, h, hd)
    return out[:, :sq]


def _cache_update(cache, k, v, positions, s: int, C: int):
    """Write new KV into the (possibly sequence-sharded) ring cache.

    Scatter with traced indices would make GSPMD all-gather the WHOLE cache
    per step (measured: 82 GB/step on granite decode_32k). Instead:
      s == 1 : dynamic-update-slice at a scalar slot — each shard resolves
               locally whether the write lands in its range; zero gathers.
      s >= C : the new tokens overwrite the entire ring (SWA prefill):
               jnp.roll of the last C entries, no scatter.
      else   : general scatter (host-side engine path; never lowered in the
               production decode cells).

    Per-row caches (``len``: [B], ``pos``: [B, C] — the slot-batched decode
    layout where each row sits at its own position) dispatch to
    ``_cache_update_per_row`` instead.
    """
    if cache["len"].ndim:
        return _cache_update_per_row(cache, k, v, positions, s, C)
    kd, vd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    if s == 1:
        slot = cache["len"] % C
        k_cached = jax.lax.dynamic_update_slice_in_dim(cache["k"], kd,
                                                       slot, 1)
        v_cached = jax.lax.dynamic_update_slice_in_dim(cache["v"], vd,
                                                       slot, 1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(cache["pos"].dtype), slot, 0)
    elif s >= C:
        # ring holds exactly the last C tokens; slot of the oldest kept
        # token is (len + s - C) % C => roll into place
        shift = (cache["len"] + s) % C
        k_cached = jnp.roll(kd[:, -C:], shift, axis=1)
        v_cached = jnp.roll(vd[:, -C:], shift, axis=1)
        cpos = jnp.roll(positions[-C:].astype(cache["pos"].dtype), shift)
    else:
        slot = (cache["len"] + jnp.arange(s)) % C
        k_cached = cache["k"].at[:, slot].set(kd)
        v_cached = cache["v"].at[:, slot].set(vd)
        cpos = cache["pos"].at[slot].set(positions)
    return k_cached, v_cached, cpos


def _cache_update_per_row(cache, k, v, positions, s: int, C: int):
    """Ring-cache write when every row has its own length/positions.

    cache: {"k"/"v": [B, C, H, hd], "pos": [B, C], "len": [B]};
    positions: [B, s]. The decode hot path (s == 1) stays scatter-free: a
    one-hot row-slot select lets each GSPMD shard resolve its own writes
    locally, exactly like the scalar dynamic-update-slice above.
    """
    kd, vd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    pd = positions.astype(cache["pos"].dtype)
    if s == 1:
        slot = cache["len"] % C                             # [B]
        oh = jnp.arange(C)[None, :] == slot[:, None]        # [B, C]
        k_cached = jnp.where(oh[..., None, None], kd, cache["k"])
        v_cached = jnp.where(oh[..., None, None], vd, cache["v"])
        cpos = jnp.where(oh, pd, cache["pos"])
        return k_cached, v_cached, cpos
    if s >= C:
        # only the last C tokens survive in the ring (SWA prefill)
        kd, vd, pd = kd[:, -C:], vd[:, -C:], pd[:, -C:]
        offs = jnp.arange(C) + (s - C)
    else:
        offs = jnp.arange(s)
    slot = (cache["len"][:, None] + offs[None, :]) % C      # [B, s']
    bidx = jnp.arange(cache["k"].shape[0])[:, None]
    k_cached = cache["k"].at[bidx, slot].set(kd)
    v_cached = cache["v"].at[bidx, slot].set(vd)
    cpos = cache["pos"].at[bidx, slot].set(pd)
    return k_cached, v_cached, cpos


def attention(ctx: TPCtx, p: Params, cfg, x: jax.Array, *,
              valid=None, cache: Params | None = None,
              pos_offset=0, q_chunk: int = 512, kv_chunk: int = 1024,
              kind: str | None = None, kv_override=None):
    """x: [B, S, D] -> ([B, S, D], new_cache).

    kind: mask override ("bidir" for encoder/cross); default maps
      cfg.attn_kind: full->causal, swa->swa.
    kv_override: (k, v, k_pos) — cross-attention with external KV.
    cache (decode): {"k": [B, C, Hkv, hd], "v": ..., "pos": [C] (neg =
      empty), "len": scalar}. C = window for SWA (ring buffer). Per-row
      caches ("pos": [B, C], "len": [B]) give every row its own position;
      ``pos_offset`` is then the [B] length vector and all masks/rope read
      positions[b].
    """
    b, s, d = x.shape
    hd = cfg.hd
    hq_run, hkv_run, group = attn_dims(cfg, ctx.tp)
    if kind is None:
        kind = "swa" if cfg.attn_kind == "swa" else "causal"
    q = col_dense(ctx, p["wq"], x, hq_run * hd, valid) \
        .reshape(b, s, hq_run, hd)
    # scalar offset -> [s] shared positions; [B] offset -> [B, s] per-row
    positions = jnp.asarray(pos_offset)[..., None] + jnp.arange(s)
    positions = positions if positions.ndim > 1 else positions.reshape(s)
    new_cache = cache

    if kv_override is not None:
        k, v, k_pos = kv_override
        if kind != "bidir":
            q = rope(q, positions, cfg.rope_theta)
    else:
        k = col_dense(ctx, p["wk"], x, hkv_run * hd, valid) \
            .reshape(b, s, hkv_run, hd)
        v = col_dense(ctx, p["wv"], x, hkv_run * hd, valid) \
            .reshape(b, s, hkv_run, hd)
        if kind != "bidir":
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        if cache is None:
            k_pos = positions
            # shard attention compute over query heads (TP): expand KV to
            # query heads HERE and pin both to the head layout, so GSPMD
            # never reshards mid-attention.
            if ctx.mesh is not None and hq_run % max(ctx.tp, 1) == 0:
                batch = tuple(a for a in ("pod", ctx.fsdp)
                              if a and a in ctx.mesh.axis_names) or None
                q = ctx.shard(q, batch, None, ctx.axis, None)
                if group > 1:
                    k = jnp.repeat(k, group, axis=2)
                    v = jnp.repeat(v, group, axis=2)
                    group = 1
                k = ctx.shard(k, batch, None, ctx.axis, None)
                v = ctx.shard(v, batch, None, ctx.axis, None)
        else:
            C = cache["k"].shape[1]
            k_cached, v_cached, cpos = _cache_update(
                cache, k, v, positions, s, C)
            new_cache = {"k": k_cached, "v": v_cached, "pos": cpos,
                         "len": cache["len"] + s}
            if s == 1:
                # decode: single C-sharded chunk; the grouped fast path in
                # _sdpa avoids materializing the expanded KV. PIN the
                # sequence sharding: without the constraint GSPMD reshards
                # the cache onto heads — a full f32 all-gather per layer
                # (measured 82 GB per decoded token on granite; §Perf H3).
                k, v, k_pos = k_cached, v_cached, cpos
                kv_chunk = max(kv_chunk, C)
                if ctx.mesh is not None:
                    batch = tuple(a for a in ("pod", ctx.fsdp)
                                  if a and a in ctx.mesh.axis_names) or None
                    k = ctx.shard(k, batch, ctx.axis, None, None)
                    v = ctx.shard(v, batch, ctx.axis, None, None)
            else:
                # prefill: the fresh K/V contain every cached token (the
                # cache starts empty), so attend over them with the
                # STREAMING path (O(S*chunk) tiles, head-sharded) instead
                # of materializing [S, C] scores against the cache.
                k_pos = positions
                if ctx.mesh is not None and hq_run % max(ctx.tp, 1) == 0:
                    batch = tuple(a for a in ("pod", ctx.fsdp)
                                  if a and a in ctx.mesh.axis_names) or None
                    q = ctx.shard(q, batch, None, ctx.axis, None)
                    if group > 1:
                        k = jnp.repeat(k, group, axis=2)
                        v = jnp.repeat(v, group, axis=2)
                        group = 1
                    k = ctx.shard(k, batch, None, ctx.axis, None)
                    v = ctx.shard(v, batch, None, ctx.axis, None)

    out = _sdpa_chunked(q, k, v, positions, k_pos, kind=kind,
                        window=cfg.window, kv_chunk=kv_chunk,
                        q_chunk=q_chunk, group=group)
    out = out.reshape(b, s, hq_run * hd).astype(x.dtype)
    y = row_dense(ctx, p["wo"], out)
    return y, new_cache


def cross_kv(ctx: TPCtx, p: Params, cfg, enc_out: jax.Array, valid=None):
    """Precompute cross-attention KV from encoder output (cached once)."""
    b, se, _ = enc_out.shape
    hd = cfg.hd
    _, hkv_run, _ = attn_dims(cfg, ctx.tp)
    k = col_dense(ctx, p["wk"], enc_out, hkv_run * hd, valid) \
        .reshape(b, se, hkv_run, hd)
    v = col_dense(ctx, p["wv"], enc_out, hkv_run * hd, valid) \
        .reshape(b, se, hkv_run, hd)
    return k, v, jnp.arange(se)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
               tp: int = 1, per_row: bool = False) -> Params:
    """KV ring cache. ``per_row=True`` gives every batch row its own
    position vector and length (slot-batched decode: rows advance
    independently, admission overwrites one row without recompiling)."""
    C = min(max_len, cfg.window) if cfg.attn_kind == "swa" else max_len
    _, hkv_run, _ = attn_dims(cfg, tp)
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, C, hkv_run, hd), dtype),
        "v": jnp.zeros((batch, C, hkv_run, hd), dtype),
        "pos": jnp.full((batch, C) if per_row else (C,), -(10 ** 9),
                        jnp.int32),
        "len": jnp.zeros((batch,) if per_row else (), jnp.int32),
    }
