"""h2o-danube-3-4b [dense] — llama+mistral mix, SWA. [arXiv:2401.16818; unverified]"""
from repro.configs.base import ArchConfig, register

H2O_DANUBE_3_4B = register(ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000,
    attn_kind="swa", window=4096,
))
