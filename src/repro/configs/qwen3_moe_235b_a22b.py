"""qwen3-moe-235b-a22b [moe] — 128 routed experts, top-8, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ArchConfig, register

QWEN3_MOE_235B_A22B = register(ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    n_experts=128, top_k=8, n_shared_experts=0, d_ff_expert=1536,
))
