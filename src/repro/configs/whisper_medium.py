"""whisper-medium [audio] — enc-dec; conv/audio frontend is a STUB:
input_specs() provides precomputed 1500-frame embeddings. [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig, register

WHISPER_MEDIUM = register(ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    encoder_layers=24, enc_seq=1500,
    act="gelu",
))
