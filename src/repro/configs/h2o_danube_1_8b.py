"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]"""
from repro.configs.base import ArchConfig, register

H2O_DANUBE_1_8B = register(ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000,
    attn_kind="swa", window=4096,
))
