"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (xLSTM[7:1]), d_ff=0 (block-internal
projections). [arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchConfig, register

XLSTM_125M = register(ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    ssm_kind="xlstm", ssm_state=0, slstm_every=8,  # blocks 7, ... are sLSTM
))
