"""hymba-1.5b [hybrid] — parallel attention + mamba heads per block.
[arXiv:2411.13676; hf]"""
from repro.configs.base import ArchConfig, register

HYMBA_1_5B = register(ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    ssm_kind="mamba", ssm_state=16,
    attn_kind="swa", window=1024,  # hymba uses SWA for most layers
))
