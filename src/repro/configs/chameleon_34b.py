"""chameleon-34b [vlm] — early-fusion VQ image tokens; the image tokenizer is
a STUB (token ids in the shared 65536 vocab). [arXiv:2405.09818; unverified]"""
from repro.configs.base import ArchConfig, register

CHAMELEON_34B = register(ArchConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536,
))
