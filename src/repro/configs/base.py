"""Architecture + shape registries for the assigned pool (40 cells).

Every assigned architecture is a selectable config (``--arch <id>``); each
arch is paired with the four LM shapes. ``train_*`` lowers ``train_step``;
``decode_*`` / ``long_*`` lower ``serve_step`` (one token against a KV cache
of seq_len). ``long_500k`` requires a sub-quadratic path (SWA / SSM / hybrid)
and is a structured skip for pure full-attention archs (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    # attention
    attn_kind: str = "full"      # full | swa
    window: int = 4096           # SWA window
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    # SSM / hybrid
    ssm_kind: str = ""           # "" | mamba | xlstm
    ssm_state: int = 0
    slstm_every: int = 0         # xlstm: every k-th block is sLSTM (0 = none)
    # encoder-decoder
    encoder_layers: int = 0
    enc_seq: int = 0             # whisper: 1500 precomputed frames (stub)
    # misc
    act: str = "silu"            # silu (gated) | gelu (ungated)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # CDC (the paper's technique; toggled per run)
    coded: bool = False
    code_r: int = 2
    code_layout: str = "folded"  # folded | dedicated

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SWA window or SSM state.)"""
        return self.attn_kind == "swa" or bool(self.ssm_kind)

    @property
    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.hd
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
            + self.n_heads * hd * d
        if self.ssm_kind == "xlstm":
            blk = 2 * d * 2 * d + 3 * (2 * d) * (2 * d) // 4  # rough
            per_layer = blk
        else:
            ffn = 3 * d * self.d_ff if self.act == "silu" \
                else 2 * d * self.d_ff
            if self.n_experts:
                ffe = 3 * d * self.d_ff_expert
                ffn = self.n_experts * ffe + self.n_shared_experts * ffe \
                    + d * self.n_experts
            per_layer = attn + ffn
            if self.ssm_kind == "mamba":
                per_layer += 2 * d * 2 * d + 2 * d * self.ssm_state * 2
        total = self.n_layers * per_layer
        if self.is_encdec:
            total += self.encoder_layers * per_layer + \
                self.n_layers * attn  # cross-attention
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return total

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top_k + shared)."""
        if not self.n_experts:
            return self.param_count
        d = self.d_model
        ffe = 3 * d * self.d_ff_expert
        inactive = (self.n_experts - self.top_k) * ffe * self.n_layers
        return self.param_count - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if not _REGISTRY:
        load_all()
    return _REGISTRY[name]


def all_archs() -> dict[str, ArchConfig]:
    if not _REGISTRY:
        load_all()
    return dict(_REGISTRY)


def load_all() -> None:
    """Import every config module (they self-register)."""
    from repro.configs import (chameleon_34b, deepseek_67b,  # noqa: F401
                               granite_3_8b, h2o_danube_1_8b,
                               h2o_danube_3_4b, hymba_1_5b, qwen2_moe_a2_7b,
                               qwen3_moe_235b_a22b, whisper_medium,
                               xlstm_125m)


def runnable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Is (arch x shape) a real cell or a structured skip?"""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: no sub-quadratic path for "
                       "524k decode (DESIGN.md §6)")
    return True, ""


def smoke_config(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        window=min(cfg.window, 64),
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        enc_seq=min(cfg.enc_seq, 16) if cfg.enc_seq else 0,
        slstm_every=min(cfg.slstm_every, 2) if cfg.slstm_every else 0,
    )
