"""deepseek-67b [dense] — deep llama-arch with GQA. [arXiv:2401.02954; hf]"""
from repro.configs.base import ArchConfig, register

DEEPSEEK_67B = register(ArchConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400,
))
