from repro.configs.base import (SHAPES, ArchConfig, ShapeSpec, all_archs,
                                get_arch, load_all, runnable, smoke_config)
