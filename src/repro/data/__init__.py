from repro.data.pipeline import (DataConfig, MemmapDataset, make_stream,
                                 write_corpus)
