"""Token data pipeline: synthetic + memory-mapped corpora, host-sharded.

Deterministic and restart-safe: the stream is a pure function of
(seed, step), so resuming from a checkpoint at step N reproduces exactly the
batches the failed run would have seen — the data-side half of
checkpoint/restart fault tolerance. Hosts read only their own batch shard
(data-parallel slicing by host index) so the input path scales with the
fleet instead of funnelling through one reader.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    kind: str = "synthetic"       # synthetic | memmap
    path: str | None = None       # memmap: flat uint16/uint32 token file
    host_index: int = 0
    host_count: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.host_count == 0
        return self.global_batch // self.host_count


def _synthetic_batch(cfg: DataConfig, step: int) -> np.ndarray:
    """Markov-ish synthetic tokens (not uniform noise, so loss can drop)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_index]))
    b, s = cfg.host_batch, cfg.seq_len
    base = rng.integers(0, cfg.vocab, size=(b, 1), dtype=np.int64)
    drift = rng.integers(-8, 9, size=(b, s), dtype=np.int64).cumsum(1)
    toks = (base + np.abs(drift)) % cfg.vocab
    return toks.astype(np.int32)


class MemmapDataset:
    """Flat binary token file, sampled with a deterministic per-step rng."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path and os.path.exists(cfg.path), cfg.path
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.uint16, mode="r")
        assert len(self.tokens) > cfg.seq_len + 1, "corpus too small"

    def batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_index]))
        starts = rng.integers(0, len(self.tokens) - cfg.seq_len - 1,
                              size=cfg.host_batch)
        out = np.stack([self.tokens[s:s + cfg.seq_len] for s in starts])
        return (out.astype(np.int64) % cfg.vocab).astype(np.int32)


def make_stream(cfg: DataConfig, start_step: int = 0
                ) -> Iterator[dict[str, np.ndarray]]:
    ds = MemmapDataset(cfg) if cfg.kind == "memmap" else None
    step = start_step
    while True:
        toks = ds.batch(step) if ds else _synthetic_batch(cfg, step)
        yield {"tokens": toks}
        step += 1


def write_corpus(path: str, vocab: int, n_tokens: int, seed: int = 0):
    """Generate a small corpus file (for the memmap path & examples)."""
    rng = np.random.default_rng(seed)
    # repeated phrases => learnable structure
    phrase = rng.integers(0, vocab, size=257, dtype=np.uint16)
    reps = n_tokens // len(phrase) + 1
    toks = np.tile(phrase, reps)[:n_tokens]
    noise = rng.random(n_tokens) < 0.05
    toks[noise] = rng.integers(0, vocab, noise.sum(), dtype=np.uint16)
    toks.astype(np.uint16).tofile(path)
