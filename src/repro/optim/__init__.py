from repro.optim.adamw import (AdamWConfig, apply_updates, global_norm,
                               init_state, lr_at)
