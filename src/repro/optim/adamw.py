"""AdamW + LR schedules + global-norm clipping, from scratch (no optax).

Mixed precision: params may be bf16; master copies and moments are fp32 and
inherit the parameter sharding (FSDP shards optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"  # cosine | constant


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(params: Any) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": zeros,
        "nu": jax.tree.map(jnp.zeros_like, zeros),
        # copy=True: fp32 params must NOT alias the master buffer (both
        # are donated by the train step)
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
    }


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _decay_mask(params: Any) -> Any:
    """No weight decay on 1-D params (norm gains, biases)."""
    return jax.tree.map(lambda p: jnp.asarray(p.ndim >= 2), params)


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any, state: dict
                  ) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mask = _decay_mask(params)

    def upd(m, v, g, p, use_decay):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * jnp.where(use_decay, p, 0.0)
        return m, v, p - lr * delta

    flat_m, treedef = jax.tree.flatten(state["mu"])
    flat_v = jax.tree.leaves(state["nu"])
    flat_g = jax.tree.leaves(grads)
    flat_p = jax.tree.leaves(state["master"])
    flat_mask = jax.tree.leaves(mask)
    out = [upd(m, v, g, p, dk) for m, v, g, p, dk in
           zip(flat_m, flat_v, flat_g, flat_p, flat_mask)]
    mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    master = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(
        lambda mp, p: mp.astype(p.dtype), master, params)
    new_state = {"step": step, "mu": mu, "nu": nu, "master": master}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
