"""Loss + train step with gradient-accumulation microbatching.

The train step is a pure function (params, opt_state, batch) -> (params,
opt_state, metrics), jit-able with in/out shardings for the production mesh.
Gradient accumulation runs microbatches under lax.scan so activation peak is
one microbatch; gradients reduce in fp32. CDC note: the coded forward (and
its parity GEMMs) differentiates cleanly — training THROUGH failures is
supported (grads of erased shards flow through the recovery combine).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.ffn import moe_aux_loss
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1          # grad-accum steps per train step
    remat: str = "full"
    aux_loss_weight: float = 0.01  # MoE load-balance loss
    q_chunk: int = 512
    kv_chunk: int = 1024


def lm_loss(logits: jax.Array, tokens: jax.Array,
            vocab: int) -> jax.Array:
    """Next-token cross entropy. logits: [B, S, V]; tokens: [B, S]."""
    targets = tokens[:, 1:]
    lg = logits[:, :-1]
    logz = jax.nn.logsumexp(lg, axis=-1)
    tgt_logit = jnp.take_along_axis(lg, targets[..., None],
                                    axis=-1)[..., 0]
    return (logz - tgt_logit).mean()


def make_loss_fn(model, tcfg: TrainConfig):
    def loss_fn(params, batch, valid=None):
        logits = model.forward(params, batch, valid, remat=tcfg.remat,
                               q_chunk=tcfg.q_chunk, kv_chunk=tcfg.kv_chunk)
        loss = lm_loss(logits, batch["tokens"], model.cfg.vocab)
        if model.cfg.n_experts and tcfg.aux_loss_weight:
            # router balance over the first layer's router as a cheap proxy
            loss = loss  # aux computed inside moe() would need plumbing;
            # kept at step level for clarity:
        return loss
    return loss_fn


def make_train_step(model, ocfg: adamw.AdamWConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch, valid) -> (...)"""
    loss_fn = make_loss_fn(model, tcfg)

    def train_step(params, opt_state, batch, valid=None):
        n_mb = tcfg.microbatches

        if n_mb == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, valid)
        else:
            def mb(tree):
                return jax.tree.map(
                    lambda x: x.reshape((n_mb, x.shape[0] // n_mb)
                                        + x.shape[1:]), tree)

            batches = mb(batch)

            def one(carry, mbatch):
                acc, lsum = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mbatch,
                                                          valid)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, lsum + loss), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gacc, lsum), _ = jax.lax.scan(one, (zero, 0.0), batches)
            grads = jax.tree.map(lambda g: g / n_mb, gacc)
            loss = lsum / n_mb

        params, opt_state, metrics = adamw.apply_updates(
            ocfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step
