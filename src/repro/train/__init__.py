from repro.train.train_step import TrainConfig, lm_loss, make_train_step
from repro.train.trainer import Trainer, TrainerConfig
