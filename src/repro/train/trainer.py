"""Training loop with fault tolerance: auto-resume, async checkpoints,
preemption handling, elastic re-mesh.

The loop is deliberately boring — all the interesting failure behaviour is
in the substrate: deterministic data (seed, step) streams, atomic checkpoint
directories, restore-onto-any-mesh, and CDC-coded inference for the serving
side. A SIGTERM (preemption notice) triggers a final synchronous save, which
is the TPU-fleet analogue of the paper's "the system never loses a request".
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.ckpt import AsyncCheckpointer, latest_step, restore
from repro.dist.sharding import param_shardings
from repro.data import DataConfig, make_stream
from repro.models.zoo import Model
from repro.optim import AdamWConfig, init_state
from repro.train.train_step import TrainConfig, make_train_step


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    dtype: Any = jnp.float32


class Trainer:
    def __init__(self, model: Model, tcfg: TrainerConfig,
                 ocfg: AdamWConfig, scfg: TrainConfig, dcfg: DataConfig,
                 mesh=None):
        self.model = model
        self.tcfg, self.ocfg, self.scfg, self.dcfg = tcfg, ocfg, scfg, dcfg
        self.mesh = mesh
        self._preempted = False
        self.step_fn = make_train_step(model, ocfg, scfg)
        if mesh is not None:
            self._install_sharded_step()
        else:
            self.step_fn = jax.jit(self.step_fn, donate_argnums=(0, 1))

    def _install_sharded_step(self):
        mesh = self.mesh
        fn = self.step_fn

        def wrapped(params, opt_state, batch):
            return fn(params, opt_state, batch)

        self.step_fn = jax.jit(wrapped, donate_argnums=(0, 1))

    # ------------------------------------------------------------ state ----
    def init_state(self):
        params = self.model.init(jax.random.PRNGKey(self.tcfg.seed),
                                 self.tcfg.dtype)
        params = self.model.encode_offline(params)
        opt_state = init_state(params)
        if self.mesh is not None:
            ps = param_shardings(params, self.mesh)
            params = jax.device_put(params, ps)
            opt_state = jax.device_put(opt_state, {
                "step": NamedSharding(self.mesh, PartitionSpec()),
                "mu": ps, "nu": ps, "master": ps})
        return params, opt_state

    def maybe_resume(self, params, opt_state):
        step = latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return params, opt_state, 0
        tree = restore({"params": params, "opt": opt_state},
                       self.tcfg.ckpt_dir, step)
        tree["params"] = self.model.encode_offline(tree["params"])
        return tree["params"], tree["opt"], step

    # ------------------------------------------------------------- loop ----
    def run(self, resume: bool = True) -> dict:
        params, opt_state = self.init_state()
        start = 0
        if resume:
            params, opt_state, start = self.maybe_resume(params, opt_state)
        ckpt = AsyncCheckpointer(self.tcfg.ckpt_dir)
        old = signal.signal(signal.SIGTERM, self._on_sigterm)

        stream = make_stream(self.dcfg, start_step=start)
        losses = []
        t0 = time.time()
        try:
            for step in range(start, self.tcfg.steps):
                batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
                params, opt_state, metrics = self.step_fn(params, opt_state,
                                                          batch)
                if (step + 1) % self.tcfg.log_every == 0 or \
                        step == self.tcfg.steps - 1:
                    loss = float(metrics["loss"])
                    losses.append((step + 1, loss))
                if (step + 1) % self.tcfg.ckpt_every == 0:
                    ckpt.save({"params": params, "opt": opt_state}, step + 1)
                if self._preempted:
                    # final synchronous save, then bail (restartable)
                    from repro.ckpt import save as sync_save
                    sync_save({"params": params, "opt": opt_state},
                              self.tcfg.ckpt_dir, step + 1)
                    break
        finally:
            ckpt.close()
            signal.signal(signal.SIGTERM, old)
        wall = time.time() - t0
        return {"losses": losses, "wall_s": wall,
                "final_step": losses[-1][0] if losses else start,
                "params": params}

    def _on_sigterm(self, *_):
        self._preempted = True
