"""Beyond the paper (§7/Fig. 18): MDS parity shards tolerate r failures.

Sweeps r = 1..4 on a T=8 output split; shows exact recovery for every
r-subset of failures tried, at (T+r)/T hardware cost — the paper's sketch
made rigorous with a totally-positive Vandermonde generator.

Run:  PYTHONPATH=src python examples/multi_failure.py
"""
import itertools

import jax
import jax.numpy as jnp

from repro.core import (CodedDenseSpec, CodeSpec, coded_matmul,
                        make_parity_weights, max_decode_condition)

T = 8
kx, kw = jax.random.split(jax.random.PRNGKey(0))
x = jax.random.normal(kx, (4, 128))
w = jax.random.normal(kw, (128, 256)) / 12.0
ref = x @ w

for r in (1, 2, 3, 4):
    spec = CodedDenseSpec(CodeSpec(T, r), layout="dedicated")
    cond = max_decode_condition(spec.code)
    w_cdc = make_parity_weights(w, spec)
    worst = 0.0
    for dead in itertools.islice(itertools.combinations(range(T), r), 20):
        valid = jnp.ones(T, bool).at[jnp.asarray(dead)].set(False)
        y = coded_matmul(x, w, w_cdc, spec, valid)
        worst = max(worst, float(jnp.abs(y - ref).max()))
    print(f"r={r}: tolerates any {r} failures | hw cost {(T + r) / T:.3f}x "
          f"(vs {r + 1:.1f}x modular) | worst err {worst:.2e} "
          f"| decode cond {cond:.1e}")
