"""Quickstart: the paper's CDC technique in 40 lines.

Builds a coded output-split GEMM (paper Eq. 7/11), kills a shard, and shows
the recovery combine reproducing the fault-free result — then the same thing
through a whole transformer.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch, smoke_config
from repro.core import CodedDenseSpec, CodeSpec, coded_matmul, \
    make_parity_weights
from repro.models import TPCtx, build

# ---- 1. one coded GEMM -----------------------------------------------------
T = 4                                   # output-split across 4 devices
spec = CodedDenseSpec(CodeSpec(n_shards=T, n_parity=2))  # folded layout
kx, kw = jax.random.split(jax.random.PRNGKey(0))
x = jax.random.normal(kx, (8, 256))
w = jax.random.normal(kw, (256, 512)) / 16.0

w_cdc = make_parity_weights(w, spec)    # OFFLINE (paper §5.2): no inputs
ref = x @ w

dead = jnp.ones(T, bool).at[2].set(False)       # device 2 dies
y = coded_matmul(x, w, w_cdc, spec, dead)       # recovery fused in
print("1. coded GEMM: max |recovered - fault-free| =",
      float(jnp.abs(y - ref).max()))

# ---- 2. a whole model under failure ----------------------------------------
cfg = smoke_config(get_arch("granite-3-8b"))
model = build(cfg, TPCtx(tp=T, mode="coded", code_r=2))
params = model.init(jax.random.PRNGKey(1))
batch = model.dummy_batch(jax.random.PRNGKey(2), 2, 16)

logits_ok = model.forward(params, batch, jnp.ones(T, bool))
logits_dead = model.forward(params, batch, dead)
print("2. full model: max logit deviation under a dead shard =",
      float(jnp.abs(logits_ok - logits_dead).max()))

# ---- 3. the cost structure (paper §5.2 benefit 1) ---------------------------
print(f"3. hardware cost: CDC {(T + 1) / T:.2f}x vs 2MR 2.00x "
      f"(constant vs linear in devices)")
