"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the xlstm-125m assigned architecture (closest to 100M) at full config
but short sequence on CPU; pass --full-seq on a real fleet. Checkpoints,
auto-resumes, and logs loss. ~15 min on this container with default args.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse

import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import DataConfig
from repro.models import TPCtx, build
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_arch("xlstm-125m")  # 12L x 768d: ~125M params, full config
    model = build(cfg, TPCtx())
    trainer = Trainer(
        model,
        TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt,
                      ckpt_every=50, log_every=10),
        AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=20),
        TrainConfig(microbatches=1, remat="none"),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch),
    )
    out = trainer.run()
    print("step,loss")
    for s, l in out["losses"]:
        print(f"{s},{l:.4f}")
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"# loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({out['wall_s']:.0f}s)")


if __name__ == "__main__":
    main()
