"""Serve a request stream through the coded cluster runtime with a
mid-stream shard failure.

Reproduces the paper's Case Study II operationally, but under sustained
load instead of a single batch: six requests flow through a 2-slot
continuous-batching scheduler driven by the BATCHED slot executor (the
whole pool advances in one jitted dispatch per round); a shard dies while
requests are decoding. The shard-health controller flips the validity
mask, the coded GEMMs recover inside the same dispatch for every slot at
once, and every request completes with tokens IDENTICAL to the fault-free
run ("the system never loses a request", §6). Measured wall-clock round
latency is reported next to the paper's modelled straggler numbers.

Run:  PYTHONPATH=src python examples/serve_cdc.py
"""
import jax
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core.failure import StragglerModel
from repro.models import TPCtx, build
from repro.runtime import (ContinuousBatchingScheduler, RuntimeConfig,
                           ShardHealthController, erasure, run_arrivals)
from repro.serve import ModelStepper

cfg = smoke_config(get_arch("h2o-danube-1.8b"))
ctx = TPCtx(tp=4, mode="coded", code_r=2, moe_capacity=0)
model = build(cfg, ctx)
params = model.init(jax.random.PRNGKey(0))

rng = np.random.default_rng(1)
arrivals = [(i * 2.0, rng.integers(0, cfg.vocab, 12), 12)
            for i in range(6)]


def serve(events):
    stepper = ModelStepper(model, params, max_len=64)
    health = ShardHealthController(stepper.n_shards,
                                   stepper.erasure_budget, events=events)
    sched = ContinuousBatchingScheduler(
        stepper, RuntimeConfig(n_slots=2), health=health)
    done = run_arrivals(sched, list(arrivals))
    return sched, {r.rid: r.tokens for r in done}


sched_ok, toks_ok = serve([])
sched_fail, toks_fail = serve([erasure(5.0, 1)])   # shard 1 dies mid-stream

print("fault-free tokens[req 0]:", toks_ok[0])
print("with-failure tokens[req 0]:", toks_fail[0])
print("all requests completed:", len(toks_fail) == len(arrivals))
print("identical across all requests:", toks_ok == toks_fail)
print("runtime metrics:", sched_fail.metrics.counters)
ex = sched_fail.executor
print(f"batched executor: {ex.vstep.n_dispatches} single-dispatch rounds, "
      f"{ex.vstep.n_traces} compile(s)")
print("measured round latency:",
      sched_fail.metrics.snapshot()["round_latency_measured"])
print("modelled straggler first-T-of-(T+r):",
      sched_fail.stepper.straggler_latency(StragglerModel(), n_trials=5000))
