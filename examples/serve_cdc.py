"""Serve a small model with batched requests + mid-request failure.

Reproduces the paper's Case Study II operationally: a shard dies while a
batch of requests is generating; the coded engine recovers inside the step
and the generated tokens are IDENTICAL to the fault-free run ("the system
never loses a request", §6).

Run:  PYTHONPATH=src python examples/serve_cdc.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, smoke_config
from repro.core.failure import StragglerModel
from repro.models import TPCtx, build
from repro.serve import ServeConfig, ServingEngine

cfg = smoke_config(get_arch("h2o-danube-1.8b"))
ctx = TPCtx(tp=4, mode="coded", code_r=2, moe_capacity=0)
model = build(cfg, ctx)
params = model.init(jax.random.PRNGKey(0))
scfg = ServeConfig(max_len=64, batch=4, cache_dtype=jnp.float32)

prompts = model.dummy_batch(jax.random.PRNGKey(1), 4, 12)

eng_ok = ServingEngine(model, params, scfg)
toks_ok = eng_ok.generate(prompts, 12)

eng_fail = ServingEngine(model, params, scfg)
toks_fail = eng_fail.generate(prompts, 12, fail_at={3: 1})  # shard 1 dies

print("fault-free tokens[0]:", toks_ok[0].tolist())
print("with-failure tokens[0]:", toks_fail[0].tolist())
print("identical:", bool(np.array_equal(toks_ok, toks_fail)))
print("metrics:", eng_fail.metrics)
print("straggler first-T-of-(T+r):",
      eng_fail.straggler_latency(StragglerModel(), n_trials=5000))
